//! PJRT backend: the AOT-compiled HLO artifact (low-rank error
//! surrogate) behind the unified [`Backend`] trait.
//!
//! The executable is OP-agnostic; reconfiguration = input buffers
//! (DESIGN.md).  `prepare` builds one [`runtime::OpBuffers`] bundle per
//! ladder rung — U/V low-rank error tables for the assigned multiplier
//! plus the (BN-overlaid) gamma/beta/bias tensors, *pre-minted as
//! literals* — so `forward` only mints the `x` literal and executes;
//! the zero-pad scratch for partial tail chunks is likewise kept
//! resident per export batch instead of reallocated per call.
//!
//! The artifact is compiled for a fixed `export_batch`; `forward`
//! accepts any batch size by chunking, zero-padding the final partial
//! chunk and truncating its logits, which is what lets the batching
//! server drive this backend with the same code path as the native one.
//!
//! BN overlays: an operating point named `op{i}` picks up
//! `bn_op{i}.qten` from the experiment directory when stage B has
//! produced it (full-retrain overlays change conv weights, which the
//! AOT artifact cannot absorb — only the native backend honors those).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::backend::Backend;
use crate::engine::OperatingPoint;
use crate::runtime::{self, LoadedModel, OpBuffers, Runtime};
use crate::util::tensorio::{self, Tensor};

/// The AOT-compiled HLO artifact (low-rank error surrogate) behind the
/// [`Backend`] trait; see the module docs for the buffer strategy.
pub struct PjrtBackend {
    // the client must outlive the executable compiled on it
    runtime: Runtime,
    model: LoadedModel,
    /// one input bundle per prepared operating point
    bufs: Vec<OpBuffers>,
    lowrank_u: Vec<Vec<f32>>,
    lowrank_v: Vec<Vec<f32>>,
    max_rank: usize,
    tensors: HashMap<String, Tensor>,
    exp_dir: PathBuf,
    /// [H, W, C]
    input_shape: Vec<usize>,
    num_classes: usize,
    /// apply `bn_op{i}.qten` overlays in `prepare` (mode != "none")
    bn_overlays: bool,
    /// reusable `[export_batch * elems]` buffer for zero-padding the
    /// final partial chunk of a batch (allocated once, per export batch)
    pad_scratch: Vec<f32>,
}

impl PjrtBackend {
    /// Load + compile the model artifact of one experiment.
    ///
    /// `artifacts` is the root artifacts directory (holds `lowrank.bin`),
    /// `exp_dir` the experiment directory (holds `model.hlo.txt`,
    /// `hlo_signature.json`, `params.qten` and the BN overlays).
    pub fn open(
        artifacts: impl AsRef<Path>,
        exp_dir: impl AsRef<Path>,
        input_shape: &[usize],
        num_classes: usize,
    ) -> Result<Self> {
        let exp_dir = exp_dir.as_ref().to_path_buf();
        if input_shape.len() != 3 {
            bail!("input shape must be [H, W, C], got {input_shape:?}");
        }
        let rt = Runtime::cpu()?;
        let model = rt.load(&exp_dir, "model")?;
        let (lowrank_u, lowrank_v, max_rank) = runtime::load_lowrank(&artifacts)?;
        let tensors = tensorio::load(exp_dir.join("params.qten"))?;
        Ok(PjrtBackend {
            runtime: rt,
            model,
            bufs: Vec::new(),
            lowrank_u,
            lowrank_v,
            max_rank,
            tensors,
            exp_dir,
            input_shape: input_shape.to_vec(),
            num_classes,
            bn_overlays: true,
            pad_scratch: Vec::new(),
        })
    }

    /// Enable/disable the BN overlay lookup (the `--mode none` path);
    /// takes effect at the next `prepare`.
    pub fn set_bn_overlays(&mut self, enabled: bool) {
        self.bn_overlays = enabled;
    }

    /// PJRT platform name the runtime compiled for (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.runtime.platform()
    }

    /// Batch size the HLO artifact was exported with; `forward` chunks
    /// and zero-pads arbitrary batch sizes onto this.
    pub fn export_batch(&self) -> usize {
        self.model.export_batch
    }

    /// BN overlay tensors for one OP: `op{i}` -> `bn_op{i}.qten` when the
    /// stage-B retraining has produced it; empty otherwise.
    fn overlay_for(&self, op: &OperatingPoint) -> Result<HashMap<String, Tensor>> {
        if !self.bn_overlays {
            return Ok(HashMap::new());
        }
        if let Some(idx) = op.name.strip_prefix("op").and_then(|s| s.parse::<usize>().ok()) {
            let path = self.exp_dir.join(format!("bn_op{idx}.qten"));
            if path.exists() {
                return tensorio::load(&path);
            }
        }
        Ok(HashMap::new())
    }
}

impl Backend for PjrtBackend {
    fn prepare(&mut self, ops: &[OperatingPoint]) -> Result<()> {
        let mut bufs = Vec::with_capacity(ops.len());
        for op in ops {
            let overlay = self.overlay_for(op)?;
            bufs.push(runtime::build_op_buffers(
                &self.model,
                &op.assignment,
                &self.lowrank_u,
                &self.lowrank_v,
                self.max_rank,
                &self.tensors,
                &overlay,
            )?);
        }
        self.bufs = bufs;
        Ok(())
    }

    fn forward(&mut self, op_idx: usize, images: &[f32], batch: usize) -> Result<Vec<f32>> {
        let bufs = self
            .bufs
            .get(op_idx)
            .with_context(|| format!("operating point {op_idx} not prepared"))?;
        let elems: usize = self.input_shape.iter().product();
        if images.len() != batch * elems {
            bail!("input size {} != expected {}", images.len(), batch * elems);
        }
        let eb = self.model.export_batch;
        let shape = [eb, self.input_shape[0], self.input_shape[1], self.input_shape[2]];
        let mut out = Vec::with_capacity(batch * self.num_classes);
        let mut i = 0;
        while i < batch {
            let b = eb.min(batch - i);
            let x = if b == eb {
                runtime::literal_f32(&images[i * elems..(i + eb) * elems], &shape)?
            } else {
                // partial tail: zero-pad to the compiled batch (reusing
                // the resident scratch buffer), truncate logits below
                if self.pad_scratch.len() != eb * elems {
                    self.pad_scratch = vec![0f32; eb * elems];
                }
                self.pad_scratch[..b * elems]
                    .copy_from_slice(&images[i * elems..(i + b) * elems]);
                self.pad_scratch[b * elems..].fill(0.0);
                runtime::literal_f32(&self.pad_scratch, &shape)?
            };
            let logits = self.model.execute_with_op(x, bufs)?;
            out.extend_from_slice(&logits[..b * self.num_classes]);
            i += b;
        }
        Ok(out)
    }

    fn name(&self) -> &str {
        "pjrt"
    }

    fn num_classes(&self) -> usize {
        self.num_classes
    }
}
