//! Stub backend: a deterministic, model-free [`Backend`] for unit tests
//! and benchmarks of everything *around* inference — the batching
//! server, the scaling supervisor, the QoS controller, the evaluate
//! loop.
//!
//! Logits are a pure function of each image's first element: with C
//! classes and `x0 = image[0] as usize % C`, class `c` scores
//! `C - ((c - x0) mod C)`, i.e. strictly descending from `x0` cycling
//! upward.  So argmax == `x0` and the top-5 set is `{x0, x0+1, ..,
//! x0+4} mod C` — accuracy expectations can be computed by hand.

use std::collections::HashMap;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::backend::Backend;
use crate::engine::OperatingPoint;
use crate::nn::ModelParams;

/// A parameter-free [`OperatingPoint`] for stub-backed tests and
/// benches: the stub never reads params, so only `name` and
/// `relative_power` (which drive the QoS ladder) matter.
pub fn stub_op(name: &str, relative_power: f64) -> OperatingPoint {
    OperatingPoint {
        name: name.to_string(),
        assignment: HashMap::new(),
        params: ModelParams {
            layers: HashMap::new(),
        },
        relative_power,
    }
}

/// Deterministic in-memory [`Backend`] (see the module docs for the
/// logit function).
pub struct StubBackend {
    classes: usize,
    /// number of operating points seen by `prepare`; 0 = not prepared
    /// (forward then accepts any index, for trait-free harness tests)
    prepared: usize,
    /// simulated compute time per `forward` call (zero by default)
    delay: Duration,
    /// (op_idx, batch) log of every forward call, for assertions
    pub forward_calls: Vec<(usize, usize)>,
}

impl StubBackend {
    /// A stub classifier with `classes` output classes.
    pub fn new(classes: usize) -> Self {
        assert!(classes > 0);
        StubBackend {
            classes,
            prepared: 0,
            delay: Duration::ZERO,
            forward_calls: Vec::new(),
        }
    }

    /// Make every `forward` call sleep for `delay`, simulating a slow
    /// substrate — lets server tests build real queue pressure (and
    /// exercise the scaling supervisor) without a model.
    pub fn with_delay(mut self, delay: Duration) -> Self {
        self.delay = delay;
        self
    }

    /// Number of operating points the last `prepare` made resident.
    pub fn prepared_ops(&self) -> usize {
        self.prepared
    }
}

impl Backend for StubBackend {
    fn prepare(&mut self, ops: &[OperatingPoint]) -> Result<()> {
        self.prepared = ops.len();
        Ok(())
    }

    fn forward(&mut self, op_idx: usize, images: &[f32], batch: usize) -> Result<Vec<f32>> {
        if self.prepared > 0 && op_idx >= self.prepared {
            bail!("operating point {op_idx} not prepared (have {})", self.prepared);
        }
        if batch == 0 || images.len() % batch != 0 || images.is_empty() {
            bail!("bad stub input: {} elems for batch {batch}", images.len());
        }
        self.forward_calls.push((op_idx, batch));
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        let elems = images.len() / batch;
        let c = self.classes;
        let mut out = Vec::with_capacity(batch * c);
        for bi in 0..batch {
            let x0 = images[bi * elems].max(0.0) as usize % c;
            for cls in 0..c {
                out.push((c - ((cls + c - x0) % c)) as f32);
            }
        }
        Ok(out)
    }

    fn name(&self) -> &str {
        "stub"
    }

    fn num_classes(&self) -> usize {
        self.classes
    }
}
