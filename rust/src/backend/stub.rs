//! Stub backend: a deterministic, model-free [`Backend`] for unit tests
//! and benchmarks of everything *around* inference — the batching
//! server, the scaling supervisor, the QoS controller, the evaluate
//! loop.
//!
//! Logits are a pure function of each image's first element: with C
//! classes and `x0 = image[0] as usize % C`, class `c` scores
//! `C - ((c - x0) mod C)`, i.e. strictly descending from `x0` cycling
//! upward.  So argmax == `x0` and the top-5 set is `{x0, x0+1, ..,
//! x0+4} mod C` — accuracy expectations can be computed by hand.

use std::collections::HashMap;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::backend::Backend;
use crate::engine::OperatingPoint;
use crate::nn::ModelParams;

/// A parameter-free [`OperatingPoint`] for stub-backed tests and
/// benches: the stub never reads params, so only `name` and
/// `relative_power` (which drive the QoS ladder) matter.
pub fn stub_op(name: &str, relative_power: f64) -> OperatingPoint {
    OperatingPoint {
        name: name.to_string(),
        assignment: HashMap::new(),
        params: ModelParams {
            layers: HashMap::new(),
        },
        relative_power,
    }
}

/// Deterministic in-memory [`Backend`] (see the module docs for the
/// logit function).
pub struct StubBackend {
    classes: usize,
    /// number of operating points seen by `prepare`; 0 = not prepared
    /// (forward then accepts any index, for trait-free harness tests)
    prepared: usize,
    /// simulated compute time per `forward` call (zero by default)
    delay: Duration,
    /// scale `delay` by the OP's relative power (see
    /// [`with_op_delay_scaling`](Self::with_op_delay_scaling))
    op_delay_scaling: bool,
    /// per-OP relative powers recorded at `prepare`, for delay scaling
    op_powers: Vec<f64>,
    /// (op_idx, batch) log of every forward call, for assertions
    pub forward_calls: Vec<(usize, usize)>,
}

impl StubBackend {
    /// A stub classifier with `classes` output classes.
    pub fn new(classes: usize) -> Self {
        assert!(classes > 0);
        StubBackend {
            classes,
            prepared: 0,
            delay: Duration::ZERO,
            op_delay_scaling: false,
            op_powers: Vec::new(),
            forward_calls: Vec::new(),
        }
    }

    /// Make every `forward` call sleep for `delay`, simulating a slow
    /// substrate — lets server tests build real queue pressure (and
    /// exercise the scaling supervisor) without a model.
    pub fn with_delay(mut self, delay: Duration) -> Self {
        self.delay = delay;
        self
    }

    /// Number of operating points the last `prepare` made resident.
    pub fn prepared_ops(&self) -> usize {
        self.prepared
    }

    /// Scale the simulated `forward` delay by the active OP's relative
    /// power (normalized to the most expensive rung), so frugal rungs
    /// really are faster — the causal link an SLO autopilot exploits
    /// when it sheds accuracy to recover latency.  No-op until
    /// `prepare` has recorded the ladder's powers.
    pub fn with_op_delay_scaling(mut self) -> Self {
        self.op_delay_scaling = true;
        self
    }

    /// The effective `forward` sleep for `op_idx` under the current
    /// scaling policy.
    fn delay_for(&self, op_idx: usize) -> Duration {
        if !self.op_delay_scaling || self.op_powers.is_empty() {
            return self.delay;
        }
        let max = self.op_powers.iter().cloned().fold(0.0f64, f64::max);
        if max <= 0.0 {
            return self.delay;
        }
        let power = self.op_powers.get(op_idx).copied().unwrap_or(max);
        self.delay.mul_f64((power / max).clamp(0.0, 1.0))
    }
}

impl Backend for StubBackend {
    fn prepare(&mut self, ops: &[OperatingPoint]) -> Result<()> {
        self.prepared = ops.len();
        self.op_powers = ops.iter().map(|o| o.relative_power).collect();
        Ok(())
    }

    fn forward(&mut self, op_idx: usize, images: &[f32], batch: usize) -> Result<Vec<f32>> {
        if self.prepared > 0 && op_idx >= self.prepared {
            bail!("operating point {op_idx} not prepared (have {})", self.prepared);
        }
        if batch == 0 || images.len() % batch != 0 || images.is_empty() {
            bail!("bad stub input: {} elems for batch {batch}", images.len());
        }
        self.forward_calls.push((op_idx, batch));
        let delay = self.delay_for(op_idx);
        if !delay.is_zero() {
            std::thread::sleep(delay);
        }
        let elems = images.len() / batch;
        let c = self.classes;
        let mut out = Vec::with_capacity(batch * c);
        for bi in 0..batch {
            let x0 = images[bi * elems].max(0.0) as usize % c;
            for cls in 0..c {
                out.push((c - ((cls + c - x0) % c)) as f32);
            }
        }
        Ok(out)
    }

    fn name(&self) -> &str {
        "stub"
    }

    fn num_classes(&self) -> usize {
        self.classes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_delay_scaling_shortens_frugal_rungs() {
        let mut be = StubBackend::new(4)
            .with_delay(Duration::from_millis(10))
            .with_op_delay_scaling();
        be.prepare(&[stub_op("exact", 1.0), stub_op("frugal", 0.5)]).unwrap();
        assert_eq!(be.delay_for(0), Duration::from_millis(10));
        assert_eq!(be.delay_for(1), Duration::from_millis(5));

        // off by default: both rungs sleep the full delay
        let mut plain = StubBackend::new(4).with_delay(Duration::from_millis(10));
        plain.prepare(&[stub_op("exact", 1.0), stub_op("frugal", 0.5)]).unwrap();
        assert_eq!(plain.delay_for(1), Duration::from_millis(10));
    }
}
