//! Native LUT backend: the bit-exact deployment semantics of the paper's
//! approximate hardware, behind the unified [`Backend`] trait.
//!
//! `prepare` precompiles the per-OP transposed-weight caches and every
//! assigned multiplier's transposed LUT via [`Engine::prepare_op`], so
//! `forward` is a pure compute path — no allocation or cache population
//! happens per batch, and OP switching is just a different index.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::backend::Backend;
use crate::engine::lutmm::LutKernel;
use crate::engine::{Engine, OperatingPoint};
use crate::muldb::MulDb;
use crate::nn::Graph;

/// The bit-exact LUT engine behind the [`Backend`] trait; see the
/// module docs for the prepare/forward contract.
pub struct NativeBackend {
    engine: Engine,
    ops: Vec<OperatingPoint>,
    num_classes: usize,
}

impl NativeBackend {
    /// Wrap a model graph + multiplier family.  Cheap — all per-OP
    /// caches are built later, in `prepare`.  Runs the host's default
    /// matmul kernel (`lutmm::default_kernel`).
    pub fn new(graph: Arc<Graph>, db: Arc<MulDb>) -> Self {
        let num_classes = graph.approx_layers().last().map(|n| n.cout).unwrap_or(10);
        NativeBackend {
            engine: Engine::new(graph, db),
            ops: Vec::new(),
            num_classes,
        }
    }

    /// Like [`new`](Self::new), but running a specific [`LutKernel`]
    /// (the CLI's `--kernel scalar|avx2|threaded|auto`).
    pub fn with_kernel(graph: Arc<Graph>, db: Arc<MulDb>, kernel: Arc<dyn LutKernel>) -> Self {
        let mut be = Self::new(graph, db);
        be.engine.set_kernel(kernel);
        be
    }

    /// Name of the matmul kernel the engine dispatches to.
    pub fn kernel_name(&self) -> &str {
        self.engine.kernel().name()
    }

    /// The underlying engine (selftest-style direct access).
    pub fn engine_mut(&mut self) -> &mut Engine {
        &mut self.engine
    }
}

impl Backend for NativeBackend {
    fn prepare(&mut self, ops: &[OperatingPoint]) -> Result<()> {
        for op in ops {
            self.engine.prepare_op(op)?;
        }
        self.ops = ops.to_vec();
        Ok(())
    }

    fn forward(&mut self, op_idx: usize, images: &[f32], batch: usize) -> Result<Vec<f32>> {
        let op = self
            .ops
            .get(op_idx)
            .with_context(|| format!("operating point {op_idx} not prepared"))?;
        self.engine.forward(op, images, batch)
    }

    fn name(&self) -> &str {
        "native"
    }

    fn num_classes(&self) -> usize {
        self.num_classes
    }
}
