//! Unified inference backend: one serving/eval API over every execution
//! substrate.
//!
//! The paper's core claim is that operating-point switching is cheap
//! because the *same* multiplier instances are reassigned to layers at
//! runtime (QoS-Nets Sec. 4).  The repo realizes inference twice — the
//! bit-exact native LUT engine and the PJRT low-rank path — and this
//! module is the seam that lets the server, the QoS controller and the
//! eval loops run on either substrate through a single trait:
//!
//!   * [`Backend`]       prepare an OP ladder once, then `forward` by index
//!   * [`OpTable`]       the shared, immutable ladder of operating points
//!   * [`NativeBackend`] wraps [`crate::engine::Engine`] (bit-exact LUTs)
//!   * `PjrtBackend`     wraps the PJRT runtime (AOT HLO, low-rank error);
//!     behind the `pjrt` cargo feature, which needs the `xla_extension`
//!     archive at build time
//!   * [`StubBackend`]   deterministic in-memory backend for tests/benches
//!   * [`evaluate`]      top-1/top-5 accuracy, written once against the trait
//!
//! Any future substrate (SIMD-blocked LUTs, sharded multi-process,
//! remote RPC) plugs in by implementing [`Backend`]; the server and CLI
//! pick it up unchanged.

pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod stub;

use anyhow::Result;

use crate::engine::OperatingPoint;
use crate::qos::LadderEntry;

pub use native::NativeBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;
pub use stub::StubBackend;

/// One inference/serving substrate.
///
/// The contract mirrors the paper's runtime model: `prepare` is called
/// once with the full operating-point ladder (reconfiguration data is
/// made resident — LUT transposes, weight transposes, PJRT input
/// buffers), after which `forward` selects an OP *by index* and must not
/// allocate or compile anything OP-dependent on the hot path.
pub trait Backend {
    /// Make every operating point resident; called once before serving.
    fn prepare(&mut self, ops: &[OperatingPoint]) -> Result<()>;

    /// Forward a batch under the `op_idx`-th prepared operating point:
    /// images `[batch, H, W, C]` f32 -> logits `[batch, classes]`.
    fn forward(&mut self, op_idx: usize, images: &[f32], batch: usize) -> Result<Vec<f32>>;

    /// [`forward`](Backend::forward) carrying the requesting tenant's
    /// class id.  Execution substrates produce the same logits for
    /// every tenant, so the default ignores the tag; distributed
    /// backends override it to stamp the class onto wire frames so
    /// worker-side drain barriers stay scoped to one class.
    fn forward_class(
        &mut self,
        class: usize,
        op_idx: usize,
        images: &[f32],
        batch: usize,
    ) -> Result<Vec<f32>> {
        let _ = class;
        self.forward(op_idx, images, batch)
    }

    /// Short stable identifier ("native", "pjrt", ...).
    fn name(&self) -> &str;

    /// Classifier output width of the loaded model.
    fn num_classes(&self) -> usize;
}

/// The shared ladder of operating points, cheap to clone and hand to
/// every worker/controller: the single source of truth the QoS
/// controller indexes into and every [`Backend`] prepares from.
#[derive(Clone)]
pub struct OpTable {
    ops: std::sync::Arc<Vec<OperatingPoint>>,
}

impl OpTable {
    /// Wrap a non-empty ladder. Order is significant: index 0 is the
    /// most accurate rung by convention (the search writes them that way).
    pub fn new(ops: Vec<OperatingPoint>) -> Self {
        assert!(!ops.is_empty(), "operating-point table must be non-empty");
        OpTable {
            ops: std::sync::Arc::new(ops),
        }
    }

    /// The full ladder, in table order (index = `forward` op index).
    pub fn ops(&self) -> &[OperatingPoint] {
        &self.ops
    }

    /// One operating point by table index (panics when out of range).
    pub fn get(&self, idx: usize) -> &OperatingPoint {
        &self.ops[idx]
    }

    /// Number of operating points in the table.
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    /// Always false — the constructor rejects empty tables.
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// The (name, power, table-index) ladder the QoS controller
    /// consumes.  Each entry carries its index in this table, so
    /// controller answers remain valid `forward`/server indices even
    /// when the table is not stored in power-descending order.
    pub fn ladder(&self) -> Vec<LadderEntry> {
        self.ops
            .iter()
            .enumerate()
            .map(|(i, o)| LadderEntry {
                name: o.name.clone(),
                power: o.relative_power,
                table_index: i,
            })
            .collect()
    }
}

/// Top-1/Top-5 accuracy over an evaluation set.
pub struct EvalResult {
    /// Fraction of samples whose argmax logit matched the label.
    pub top1: f64,
    /// Fraction of samples whose label was among the 5 largest logits.
    pub top5: f64,
    /// Number of samples evaluated (after the `limit` cap).
    pub n: usize,
}

/// Indices of the `k` largest entries of `row`, descending; ties keep
/// the earlier index first.  Partial selection — O(C·k) instead of the
/// full O(C log C) sort, which matters at ImageNet class counts under
/// serving load.
pub fn top_k_indices(row: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(row.len());
    let mut top: Vec<usize> = Vec::with_capacity(k + 1);
    for (i, &v) in row.iter().enumerate() {
        // entries are sorted by (value desc, index asc); every resident
        // index is < i, so ties sort before the candidate
        let pos = top.partition_point(|&j| row[j] >= v);
        if pos < k {
            top.insert(pos, i);
            top.truncate(k);
        }
    }
    top
}

/// Top-1/Top-5 accuracy of one prepared operating point, written once
/// against the [`Backend`] trait (native and PJRT share this code path).
pub fn evaluate<B: Backend + ?Sized>(
    backend: &mut B,
    op_idx: usize,
    images: &[f32],
    labels: &[i32],
    image_elems: usize,
    batch: usize,
    limit: Option<usize>,
) -> Result<EvalResult> {
    let num_classes = backend.num_classes();
    let n = limit.unwrap_or(labels.len()).min(labels.len());
    let mut top1 = 0usize;
    let mut top5 = 0usize;
    let mut i = 0;
    while i < n {
        let b = batch.min(n - i);
        let chunk = &images[i * image_elems..(i + b) * image_elems];
        let logits = backend.forward(op_idx, chunk, b)?;
        for bi in 0..b {
            let row = &logits[bi * num_classes..(bi + 1) * num_classes];
            let label = labels[i + bi] as usize;
            let top = top_k_indices(row, 5);
            if top.first() == Some(&label) {
                top1 += 1;
            }
            if top.contains(&label) {
                top5 += 1;
            }
        }
        i += b;
    }
    Ok(EvalResult {
        top1: top1 as f64 / n as f64,
        top5: top5 as f64 / n as f64,
        n,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_k_matches_full_sort() {
        let mut rng = crate::util::rng::Rng::new(17);
        for classes in [1usize, 4, 5, 6, 100] {
            for _ in 0..20 {
                let row: Vec<f32> = (0..classes).map(|_| rng.normal() as f32).collect();
                let got = top_k_indices(&row, 5);
                let mut idx: Vec<usize> = (0..classes).collect();
                idx.sort_by(|&a, &c| row[c].partial_cmp(&row[a]).unwrap());
                assert_eq!(got, idx[..5.min(classes)].to_vec());
            }
        }
    }

    #[test]
    fn top_k_ties_prefer_earlier_index() {
        let row = [1.0f32, 3.0, 3.0, 2.0, 3.0];
        assert_eq!(top_k_indices(&row, 3), vec![1, 2, 4]);
        assert_eq!(top_k_indices(&row, 5), vec![1, 2, 4, 3, 0]);
    }
}
