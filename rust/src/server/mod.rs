//! Inference server: request queue -> dynamic batcher -> worker pool,
//! with live operating-point switching driven by the QoS controller.
//!
//! Architecture (std threads + mpsc; tokio is unavailable offline):
//!
//!   clients ---> ingress channel ---> batcher thread ---> worker channel
//!                                                     \--> N worker threads
//!                                                          (one Backend each)
//!
//! The server is generic over [`Backend`], so the same batching /
//! switching / metrics machinery serves the native LUT engine, the PJRT
//! runtime, or any future substrate.  Each worker constructs its own
//! backend via a factory *inside* its thread (backends need not be
//! `Send`) and calls `prepare` on the shared [`OpTable`] before taking
//! work, so the hot path never compiles or caches anything.
//!
//! The current operating point is an `Arc<AtomicUsize>` index into the
//! shared OP table; switching is a single atomic store (every backend
//! holds all OPs resident — the paper's "lightweight switching"
//! realized).

use std::marker::PhantomData;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::backend::{Backend, NativeBackend, OpTable};
use crate::engine::OperatingPoint;
use crate::muldb::MulDb;
use crate::nn::Graph;
use crate::util::stats::LatencyHistogram;

pub struct Request {
    pub id: u64,
    pub image: Vec<f32>,
    pub enqueued: Instant,
    pub resp: mpsc::Sender<Response>,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub op_index: usize,
    pub queue_us: u64,
    pub total_us: u64,
}

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub workers: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(5),
            workers: 2,
        }
    }
}

#[derive(Debug, Default, Clone)]
pub struct ServerMetrics {
    pub completed: u64,
    pub batches: u64,
    pub batch_size_sum: u64,
    pub latency: LatencyHistogram,
    pub queue_latency: LatencyHistogram,
    pub per_op_requests: Vec<u64>,
}

impl ServerMetrics {
    fn new(n_ops: usize) -> Self {
        ServerMetrics {
            per_op_requests: vec![0; n_ops],
            latency: LatencyHistogram::new(),
            queue_latency: LatencyHistogram::new(),
            ..Default::default()
        }
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_size_sum as f64 / self.batches as f64
        }
    }
}

pub struct Server<B: Backend> {
    ingress: mpsc::Sender<Request>,
    current_op: Arc<AtomicUsize>,
    ops: OpTable,
    metrics: Arc<Mutex<ServerMetrics>>,
    stop: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
    next_id: AtomicUsize,
    _backend: PhantomData<fn() -> B>,
}

impl<B: Backend + 'static> Server<B> {
    /// Start the batcher + `cfg.workers` workers.  `factory(w)` runs on
    /// worker `w`'s own thread to build its backend (backends need not
    /// be `Send`); each backend then `prepare`s the shared OP table
    /// before serving.  Blocks until every worker has reported its
    /// prepare outcome and fails if none came up — a server with zero
    /// live workers would otherwise accept requests and answer nothing.
    pub fn start<F>(factory: F, ops: OpTable, cfg: BatcherConfig) -> Result<Self>
    where
        F: Fn(usize) -> Result<B> + Send + Sync + 'static,
    {
        let factory = Arc::new(factory);
        let current_op = Arc::new(AtomicUsize::new(0));
        let metrics = Arc::new(Mutex::new(ServerMetrics::new(ops.len())));
        let stop = Arc::new(AtomicBool::new(false));

        let (ingress_tx, ingress_rx) = mpsc::channel::<Request>();
        let (batch_tx, batch_rx) = mpsc::channel::<Vec<Request>>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));

        let mut threads = Vec::new();

        // batcher thread: size- or deadline-triggered batch formation
        {
            let stop = stop.clone();
            let cfg2 = cfg.clone();
            threads.push(std::thread::spawn(move || {
                batcher_loop(ingress_rx, batch_tx, cfg2, stop);
            }));
        }

        // workers; each reports construction/prepare success or failure
        let n_workers = cfg.workers.max(1);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        for w in 0..n_workers {
            let factory = factory.clone();
            let rx = batch_rx.clone();
            let ops = ops.clone();
            let current = current_op.clone();
            let metrics = metrics.clone();
            let ready = ready_tx.clone();
            threads.push(std::thread::spawn(move || {
                let built = (*factory)(w).and_then(|mut b| {
                    b.prepare(ops.ops())?;
                    Ok(b)
                });
                let mut backend = match built {
                    Ok(b) => {
                        let _ = ready.send(Ok(()));
                        b
                    }
                    Err(e) => {
                        eprintln!("worker {w}: backend init failed: {e:#}");
                        let _ = ready.send(Err(e));
                        return;
                    }
                };
                worker_loop(&mut backend, &rx, &current, &metrics);
            }));
        }
        drop(ready_tx);

        let mut live = 0usize;
        let mut first_err: Option<anyhow::Error> = None;
        for _ in 0..n_workers {
            match ready_rx.recv() {
                Ok(Ok(())) => live += 1,
                Ok(Err(e)) => first_err = first_err.or(Some(e)),
                Err(_) => break, // worker died without reporting
            }
        }
        if live == 0 {
            stop.store(true, Ordering::Release);
            drop(ingress_tx);
            for t in threads.drain(..) {
                let _ = t.join();
            }
            return Err(first_err
                .unwrap_or_else(|| anyhow!("no inference worker came up"))
                .context("server start: every worker failed"));
        }

        Ok(Server {
            ingress: ingress_tx,
            current_op,
            ops,
            metrics,
            stop,
            threads,
            next_id: AtomicUsize::new(0),
            _backend: PhantomData,
        })
    }

    /// Submit one image; returns the response channel.
    pub fn submit(&self, image: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        let (tx, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) as u64;
        self.ingress.send(Request {
            id,
            image,
            enqueued: Instant::now(),
            resp: tx,
        })?;
        Ok(rx)
    }

    /// Atomically switch the serving operating point.
    pub fn set_operating_point(&self, idx: usize) {
        assert!(idx < self.ops.len());
        self.current_op.store(idx, Ordering::Release);
    }

    pub fn operating_point(&self) -> usize {
        self.current_op.load(Ordering::Acquire)
    }

    pub fn ops(&self) -> &[OperatingPoint] {
        self.ops.ops()
    }

    pub fn op_table(&self) -> &OpTable {
        &self.ops
    }

    pub fn metrics(&self) -> ServerMetrics {
        self.metrics.lock().unwrap().clone()
    }

    /// Drain and stop; joins all threads.
    pub fn shutdown(mut self) -> ServerMetrics {
        self.stop.store(true, Ordering::Release);
        drop(self.ingress);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        self.metrics.lock().unwrap().clone()
    }
}

impl Server<NativeBackend> {
    /// Convenience: serve the native bit-exact LUT engine (one per
    /// worker) over a shared operating-point table.
    pub fn start_native(
        graph: Arc<Graph>,
        db: Arc<MulDb>,
        ops: OpTable,
        cfg: BatcherConfig,
    ) -> Result<Self> {
        Server::start(
            move |_w| Ok(NativeBackend::new(graph.clone(), db.clone())),
            ops,
            cfg,
        )
    }
}

fn worker_loop<B: Backend>(
    backend: &mut B,
    rx: &Arc<Mutex<mpsc::Receiver<Vec<Request>>>>,
    current: &Arc<AtomicUsize>,
    metrics: &Arc<Mutex<ServerMetrics>>,
) {
    loop {
        let batch = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        let Ok(batch) = batch else { break };
        if batch.is_empty() {
            continue;
        }
        let op_idx = current.load(Ordering::Acquire);
        let started = Instant::now();
        let b = batch.len();
        let elems = batch[0].image.len();
        let mut images = Vec::with_capacity(b * elems);
        for r in &batch {
            images.extend_from_slice(&r.image);
        }
        let logits = match backend.forward(op_idx, &images, b) {
            Ok(l) => l,
            Err(e) => {
                eprintln!("{} backend: dropping batch of {b}: {e:#}", backend.name());
                continue;
            }
        };
        let classes = logits.len() / b;
        let done = Instant::now();
        let mut m = metrics.lock().unwrap();
        m.batches += 1;
        m.batch_size_sum += b as u64;
        for (i, r) in batch.into_iter().enumerate() {
            let queue_us = started.duration_since(r.enqueued).as_micros() as u64;
            let total_us = done.duration_since(r.enqueued).as_micros() as u64;
            m.completed += 1;
            m.per_op_requests[op_idx] += 1;
            m.latency.record_us(total_us);
            m.queue_latency.record_us(queue_us);
            let _ = r.resp.send(Response {
                id: r.id,
                logits: logits[i * classes..(i + 1) * classes].to_vec(),
                op_index: op_idx,
                queue_us,
                total_us,
            });
        }
    }
}

fn batcher_loop(
    ingress: mpsc::Receiver<Request>,
    out: mpsc::Sender<Vec<Request>>,
    cfg: BatcherConfig,
    stop: Arc<AtomicBool>,
) {
    let mut pending: Vec<Request> = Vec::new();
    let mut deadline: Option<Instant> = None;
    loop {
        if stop.load(Ordering::Acquire) {
            // stop requested: drain whatever is already queued, flush the
            // final partial batch and exit promptly (shutdown no longer
            // relies solely on channel disconnect)
            while let Ok(req) = ingress.try_recv() {
                pending.push(req);
                if pending.len() >= cfg.max_batch {
                    let _ = out.send(std::mem::take(&mut pending));
                }
            }
            if !pending.is_empty() {
                let _ = out.send(std::mem::take(&mut pending));
            }
            break;
        }
        let timeout = match deadline {
            Some(d) => d.saturating_duration_since(Instant::now()),
            None => Duration::from_millis(50),
        };
        match ingress.recv_timeout(timeout) {
            Ok(req) => {
                if pending.is_empty() {
                    deadline = Some(Instant::now() + cfg.max_wait);
                }
                pending.push(req);
                if pending.len() >= cfg.max_batch {
                    let _ = out.send(std::mem::take(&mut pending));
                    deadline = None;
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if !pending.is_empty() {
                    let _ = out.send(std::mem::take(&mut pending));
                    deadline = None;
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                if !pending.is_empty() {
                    let _ = out.send(std::mem::take(&mut pending));
                }
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(val: f32) -> (Request, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        (
            Request {
                id: 0,
                image: vec![val, 0.0],
                enqueued: Instant::now(),
                resp: tx,
            },
            rx,
        )
    }

    fn spawn_batcher(
        cfg: BatcherConfig,
    ) -> (
        mpsc::Sender<Request>,
        mpsc::Receiver<Vec<Request>>,
        Arc<AtomicBool>,
        std::thread::JoinHandle<()>,
    ) {
        let (in_tx, in_rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::channel();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let h = std::thread::spawn(move || batcher_loop(in_rx, out_tx, cfg, stop2));
        (in_tx, out_rx, stop, h)
    }

    #[test]
    fn batcher_flushes_when_size_reached() {
        // deadline far away: only the size trigger can flush
        let (in_tx, out_rx, _stop, h) = spawn_batcher(BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_secs(30),
            workers: 1,
        });
        let mut resp_rxs = Vec::new();
        for i in 0..4 {
            let (r, rx) = req(i as f32);
            resp_rxs.push(rx);
            in_tx.send(r).unwrap();
        }
        let batch = out_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(batch.len(), 4);
        drop(in_tx);
        h.join().unwrap();
    }

    #[test]
    fn batcher_flushes_partial_batch_at_deadline() {
        // size trigger unreachable: only the deadline can flush
        let (in_tx, out_rx, _stop, h) = spawn_batcher(BatcherConfig {
            max_batch: 100,
            max_wait: Duration::from_millis(20),
            workers: 1,
        });
        let mut resp_rxs = Vec::new();
        for i in 0..3 {
            let (r, rx) = req(i as f32);
            resp_rxs.push(rx);
            in_tx.send(r).unwrap();
        }
        let t0 = Instant::now();
        let batch = out_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(batch.len(), 3);
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "deadline flush took {:?}",
            t0.elapsed()
        );
        drop(in_tx);
        h.join().unwrap();
    }

    #[test]
    fn batcher_exits_promptly_when_stopped_and_drained() {
        let (in_tx, out_rx, stop, h) = spawn_batcher(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            workers: 1,
        });
        let (r, _resp_rx) = req(1.0);
        in_tx.send(r).unwrap();
        stop.store(true, Ordering::Release);
        let t0 = Instant::now();
        // the ingress sender stays alive: only the stop flag can end the
        // loop (this is the dead-branch regression test)
        let batches: Vec<Vec<Request>> = out_rx.iter().collect();
        h.join().unwrap();
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "stop took {:?}",
            t0.elapsed()
        );
        let total: usize = batches.iter().map(|b| b.len()).sum();
        assert_eq!(total, 1, "pending request must be flushed, not dropped");
        drop(in_tx);
    }
}
