//! Inference server: request queue -> dynamic batcher -> worker pool,
//! with live operating-point switching driven by the QoS controller.
//!
//! Architecture (std threads + mpsc; tokio is unavailable offline):
//!
//!   clients ---> ingress channel ---> batcher thread ---> worker channel
//!                                                     \--> N worker threads
//!                                                          (one Engine each)
//!
//! The current operating point is an `Arc<AtomicUsize>` index into a
//! shared OP table; switching is a single atomic store (the engine holds
//! every LUT already — the paper's "lightweight switching" realized).

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::engine::{Engine, OperatingPoint};
use crate::muldb::MulDb;
use crate::nn::Graph;
use crate::util::stats::LatencyHistogram;

pub struct Request {
    pub id: u64,
    pub image: Vec<f32>,
    pub enqueued: Instant,
    pub resp: mpsc::Sender<Response>,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub op_index: usize,
    pub queue_us: u64,
    pub total_us: u64,
}

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub workers: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(5),
            workers: 2,
        }
    }
}

#[derive(Debug, Default, Clone)]
pub struct ServerMetrics {
    pub completed: u64,
    pub batches: u64,
    pub batch_size_sum: u64,
    pub latency: LatencyHistogram,
    pub queue_latency: LatencyHistogram,
    pub per_op_requests: Vec<u64>,
}

impl ServerMetrics {
    fn new(n_ops: usize) -> Self {
        ServerMetrics {
            per_op_requests: vec![0; n_ops],
            latency: LatencyHistogram::new(),
            queue_latency: LatencyHistogram::new(),
            ..Default::default()
        }
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_size_sum as f64 / self.batches as f64
        }
    }
}

pub struct Server {
    ingress: mpsc::Sender<Request>,
    current_op: Arc<AtomicUsize>,
    ops: Arc<Vec<OperatingPoint>>,
    metrics: Arc<Mutex<ServerMetrics>>,
    stop: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
    next_id: AtomicUsize,
}

impl Server {
    pub fn start(
        graph: Arc<Graph>,
        db: Arc<MulDb>,
        ops: Vec<OperatingPoint>,
        cfg: BatcherConfig,
    ) -> Result<Self> {
        assert!(!ops.is_empty());
        let ops = Arc::new(ops);
        let current_op = Arc::new(AtomicUsize::new(0));
        let metrics = Arc::new(Mutex::new(ServerMetrics::new(ops.len())));
        let stop = Arc::new(AtomicBool::new(false));

        let (ingress_tx, ingress_rx) = mpsc::channel::<Request>();
        let (batch_tx, batch_rx) = mpsc::channel::<Vec<Request>>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));

        let mut threads = Vec::new();

        // batcher thread: size- or deadline-triggered batch formation
        {
            let stop = stop.clone();
            let cfg2 = cfg.clone();
            threads.push(std::thread::spawn(move || {
                batcher_loop(ingress_rx, batch_tx, cfg2, stop);
            }));
        }

        // workers
        for _w in 0..cfg.workers.max(1) {
            let rx = batch_rx.clone();
            let graph = graph.clone();
            let db = db.clone();
            let ops = ops.clone();
            let current = current_op.clone();
            let metrics = metrics.clone();
            threads.push(std::thread::spawn(move || {
                let mut engine = Engine::new(graph, db);
                loop {
                    let batch = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    let Ok(batch) = batch else { break };
                    if batch.is_empty() {
                        continue;
                    }
                    let op_idx = current.load(Ordering::Acquire);
                    let op = &ops[op_idx];
                    let started = Instant::now();
                    let b = batch.len();
                    let elems = batch[0].image.len();
                    let mut images = Vec::with_capacity(b * elems);
                    for r in &batch {
                        images.extend_from_slice(&r.image);
                    }
                    let logits = match engine.forward(op, &images, b) {
                        Ok(l) => l,
                        Err(_) => continue,
                    };
                    let classes = logits.len() / b;
                    let done = Instant::now();
                    let mut m = metrics.lock().unwrap();
                    m.batches += 1;
                    m.batch_size_sum += b as u64;
                    for (i, r) in batch.into_iter().enumerate() {
                        let queue_us = started.duration_since(r.enqueued).as_micros() as u64;
                        let total_us = done.duration_since(r.enqueued).as_micros() as u64;
                        m.completed += 1;
                        m.per_op_requests[op_idx] += 1;
                        m.latency.record_us(total_us);
                        m.queue_latency.record_us(queue_us);
                        let _ = r.resp.send(Response {
                            id: r.id,
                            logits: logits[i * classes..(i + 1) * classes].to_vec(),
                            op_index: op_idx,
                            queue_us,
                            total_us,
                        });
                    }
                }
            }));
        }

        Ok(Server {
            ingress: ingress_tx,
            current_op,
            ops,
            metrics,
            stop,
            threads,
            next_id: AtomicUsize::new(0),
        })
    }

    /// Submit one image; returns the response channel.
    pub fn submit(&self, image: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        let (tx, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) as u64;
        self.ingress.send(Request {
            id,
            image,
            enqueued: Instant::now(),
            resp: tx,
        })?;
        Ok(rx)
    }

    /// Atomically switch the serving operating point.
    pub fn set_operating_point(&self, idx: usize) {
        assert!(idx < self.ops.len());
        self.current_op.store(idx, Ordering::Release);
    }

    pub fn operating_point(&self) -> usize {
        self.current_op.load(Ordering::Acquire)
    }

    pub fn ops(&self) -> &[OperatingPoint] {
        &self.ops
    }

    pub fn metrics(&self) -> ServerMetrics {
        self.metrics.lock().unwrap().clone()
    }

    /// Drain and stop; joins all threads.
    pub fn shutdown(mut self) -> ServerMetrics {
        self.stop.store(true, Ordering::Release);
        drop(self.ingress);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        let m = self.metrics.lock().unwrap().clone();
        m
    }
}

fn batcher_loop(
    ingress: mpsc::Receiver<Request>,
    out: mpsc::Sender<Vec<Request>>,
    cfg: BatcherConfig,
    stop: Arc<AtomicBool>,
) {
    let mut pending: Vec<Request> = Vec::new();
    let mut deadline: Option<Instant> = None;
    loop {
        if stop.load(Ordering::Acquire) && pending.is_empty() {
            // keep draining until the channel disconnects
        }
        let timeout = match deadline {
            Some(d) => d.saturating_duration_since(Instant::now()),
            None => Duration::from_millis(50),
        };
        match ingress.recv_timeout(timeout) {
            Ok(req) => {
                if pending.is_empty() {
                    deadline = Some(Instant::now() + cfg.max_wait);
                }
                pending.push(req);
                if pending.len() >= cfg.max_batch {
                    let _ = out.send(std::mem::take(&mut pending));
                    deadline = None;
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if !pending.is_empty() {
                    let _ = out.send(std::mem::take(&mut pending));
                    deadline = None;
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                if !pending.is_empty() {
                    let _ = out.send(std::mem::take(&mut pending));
                }
                break;
            }
        }
    }
}
