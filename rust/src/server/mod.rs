//! Elastic inference server: request queue -> dynamic batcher -> worker
//! pool, with live operating-point switching driven by the QoS
//! controller and load-driven worker scaling driven by a supervisor.
//!
//! Architecture (std threads + mpsc; tokio is unavailable offline — see
//! `docs/ARCHITECTURE.md` for the full picture):
//!
//! ```text
//!   clients --> ingress channel --> batcher thread --> worker channel
//!                    |                                   \--> N workers
//!                    |                                  (one Backend each)
//!   supervisor ------+--- spawns/retires workers on queue pressure
//! ```
//!
//! The server is generic over [`Backend`], so the same batching /
//! switching / scaling / metrics machinery serves the native LUT engine,
//! the PJRT runtime, or any future substrate.  Each worker constructs
//! its own backend via a factory *inside* its thread (backends need not
//! be `Send`) and calls `prepare` on the shared [`OpTable`] before
//! taking work, so the hot path never compiles or caches anything.
//!
//! Three runtime properties this module guarantees:
//!
//! * **OP-tagged batches.**  The batcher stamps every batch with the
//!   current operating point at *formation* time; a batch never mixes
//!   logits from two OPs, and [`Response::op_index`] is exact.
//! * **Two switch disciplines.**  [`Server::set_operating_point_with`]
//!   takes a [`SwitchMode`]: `Immediate` is a single atomic store (the
//!   paper's "lightweight switching"); `Drain` installs a barrier in
//!   the batcher so every request enqueued before the switch runs under
//!   the old OP and every request after it under the new one.  With
//!   [`BatcherConfig::retag_downgrades`], already-formed batches are
//!   retagged to the current OP at execution time when it is *cheaper*
//!   than their formation tag, so an `Immediate` downgrade reaches a
//!   deep backlog too (upgrades never retag).
//! * **Elastic workers.**  When [`BatcherConfig`] allows a worker range,
//!   a supervisor thread samples queue depth and batcher wait-time
//!   watermarks every `scale_interval` and spawns (up to `max_workers`)
//!   or retires (down to `min_workers`) workers, with consecutive-tick
//!   hysteresis so the pool does not flap.  Scale-ups are *batched*: a
//!   pressured tick spawns one worker per full multiple of the depth
//!   threshold sitting in the queue ([`scale_up_count`]), so a deep
//!   burst reaches the ceiling in one tick instead of one worker per
//!   tick.

use std::marker::PhantomData;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::backend::{Backend, NativeBackend, OpTable};
use crate::engine::OperatingPoint;
use crate::muldb::MulDb;
use crate::nn::Graph;
use crate::obs::{self, metrics::{summary_families, Kind, MetricFamily, Sample}, ObsEvent};
use crate::util::stats::{LatencyHistogram, LatencySummary};

pub use crate::qos::SwitchMode;

/// One enqueued inference request.
pub struct Request {
    /// Server-assigned sequence number (monotonic per server).
    pub id: u64,
    /// Tenant class id (position in the deployment's
    /// [`crate::qos::ClassSet`]); 0 in single-tenant deployments.
    pub class: usize,
    /// Flattened `[H, W, C]` image.
    pub image: Vec<f32>,
    /// Submission timestamp; queue/total latency is measured from here.
    pub enqueued: Instant,
    /// Channel the worker answers on.
    pub resp: mpsc::Sender<Response>,
}

/// The answer to one [`Request`].
#[derive(Debug, Clone)]
pub struct Response {
    /// Echo of the request id.
    pub id: u64,
    /// Echo of the request's tenant class id (0 single-tenant).
    pub class: usize,
    /// One logit per class of the served model.
    pub logits: Vec<f32>,
    /// `OpTable` index of the operating point the batch ran under
    /// (stamped at batch formation — exact even across switches).
    pub op_index: usize,
    /// Identifier of the batch this request was served in; all
    /// responses sharing a `batch_seq` ran in one `forward` call and
    /// therefore carry the same `op_index`.
    pub batch_seq: u64,
    /// Time from submission to batch formation, microseconds.
    pub queue_us: u64,
    /// Time from submission to logits, microseconds.
    pub total_us: u64,
}

/// Batcher + worker-pool configuration.
///
/// The scaling fields are inert by default: `min_workers`/`max_workers`
/// of 0 mean "same as `workers`", i.e. a fixed pool and no supervisor
/// thread.  Set `max_workers > min_workers` to let the pool breathe.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Flush a batch as soon as it reaches this many requests.
    pub max_batch: usize,
    /// Flush a partial batch this long after its first request.
    pub max_wait: Duration,
    /// Initial worker count (clamped into `[min_workers, max_workers]`).
    pub workers: usize,
    /// Scaling floor; 0 (default) means "same as `workers`".  When it
    /// conflicts with an explicit `max_workers`, the ceiling wins.
    pub min_workers: usize,
    /// Scaling ceiling; 0 (default) means "same as `workers`".
    pub max_workers: usize,
    /// Supervisor sampling period.
    pub scale_interval: Duration,
    /// Scale up when in-flight requests exceed this many per live
    /// worker (effective threshold is at least `max_batch` per worker,
    /// so the requests inside one executing batch never count as
    /// queue pressure)...
    pub scale_up_queue: usize,
    /// ...or when the oldest request in an executing batch waited
    /// longer than `max_wait + scale_up_wait` between submission and
    /// execution start (the wait-time watermark — grows with the
    /// worker-channel backlog; the threshold sits on top of the
    /// intentional `max_wait` batching delay, so no `max_wait` value
    /// can make an unloaded server look pressured).
    pub scale_up_wait: Duration,
    /// Consecutive pressured supervisor ticks before spawning
    /// (hysteresis against transient spikes).  A qualifying tick may
    /// spawn several workers at once under a deep backlog — see
    /// [`scale_up_count`].
    pub scale_up_after: u32,
    /// Consecutive idle supervisor ticks (no meaningful backlog: at
    /// most `live/2` requests in flight and sub-threshold waits)
    /// before retiring one worker (hysteresis against brief lulls).
    pub scale_down_after: u32,
    /// Immediate-downgrade policy for *already-formed* batches.  Off
    /// (the default), a batch keeps its formation-time OP tag, so a
    /// deep backlog rides out an `Immediate` switch at the old power —
    /// strict OP-tagging's documented trade-off.  On, a worker about to
    /// execute a batch re-reads the current OP and retags the batch to
    /// it when it is *cheaper* than the formation tag (a downgrade —
    /// upgrades never retag, so accuracy is never silently spent on
    /// requests that were promised the cheaper rung).  Only `Immediate`
    /// switches arm the policy: a `Drain` switch explicitly promises
    /// pre-barrier requests the old OP, and that promise is kept even
    /// with this flag on.  The batch stays uniform and
    /// `Response::op_index` still reports the OP the batch actually ran
    /// under.
    pub retag_downgrades: bool,
    /// Tenant class count.  0 or 1 = single-tenant: one queue, one
    /// `(op, mode)` word, no class labels — byte-identical to the
    /// pre-tenancy server.  With more classes the batcher keys its
    /// pending queues per class (a batch never mixes classes), each
    /// class gets its own operating-point word and drain barrier, and
    /// batch events/metrics carry a `class` label.
    pub classes: usize,
    /// Class names in id order (from [`crate::qos::ClassSet::names`])
    /// for event and metric labels; missing entries fall back to the
    /// class id.  Ignored single-tenant.
    pub class_names: Vec<String>,
    /// Per-class admission fractions in id order (from
    /// [`crate::qos::ClassSet::admit_fracs`]); missing entries admit
    /// fully.  Only consulted when `max_inflight > 0`.
    pub admit_fracs: Vec<f64>,
    /// Admission capacity: [`Server::submit_class`] rejects a class-`c`
    /// submission once total in-flight requests reach
    /// `admit_fracs[c] * max_inflight`, so best-effort classes bounce
    /// first under overload while premium (fraction 1.0) only bounces
    /// when the deployment is hard-full.  0 (default) = unlimited;
    /// every submission is accepted and [`Server::submit`] never
    /// consults the fractions at all.
    pub max_inflight: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(5),
            workers: 2,
            min_workers: 0,
            max_workers: 0,
            scale_interval: Duration::from_millis(20),
            scale_up_queue: 8,
            scale_up_wait: Duration::from_millis(20),
            scale_up_after: 2,
            scale_down_after: 25,
            retag_downgrades: false,
            classes: 1,
            class_names: Vec::new(),
            admit_fracs: Vec::new(),
            max_inflight: 0,
        }
    }
}

/// Event/metric label value per class id: `None` single-tenant (the
/// label is omitted so series keep their pre-tenancy names), the
/// configured class name (or the id rendered as text) otherwise.
fn class_labels(cfg: &BatcherConfig) -> Vec<Option<String>> {
    let n = cfg.classes.max(1);
    if n == 1 {
        return vec![None];
    }
    (0..n)
        .map(|c| Some(cfg.class_names.get(c).cloned().unwrap_or_else(|| c.to_string())))
        .collect()
}

/// Aggregate serving metrics, cloned out under a lock.
#[derive(Debug, Default, Clone)]
pub struct ServerMetrics {
    /// Requests answered.
    pub completed: u64,
    /// Batches executed.
    pub batches: u64,
    /// Sum of executed batch sizes (for [`mean_batch`](Self::mean_batch)).
    pub batch_size_sum: u64,
    /// End-to-end latency over all requests.
    pub latency: LatencyHistogram,
    /// Submission-to-batch-formation latency over all requests.
    pub queue_latency: LatencyHistogram,
    /// Requests served per `OpTable` index.
    pub per_op_requests: Vec<u64>,
    /// End-to-end latency split by the `OpTable` index each batch
    /// actually ran under — the per-OP cost attribution the QoS
    /// power/accuracy trade-off analysis needs.
    pub per_op_latency: Vec<LatencyHistogram>,
    /// Workers spawned by the scaling supervisor.
    pub scale_ups: u64,
    /// Workers retired by the scaling supervisor.
    pub scale_downs: u64,
    /// Supervisor-spawned workers whose backend failed to initialize.
    pub spawn_failures: u64,
    /// Highest concurrently live worker count observed.
    pub peak_workers: usize,
    /// Batches retagged to a cheaper OP at execution time under the
    /// [`BatcherConfig::retag_downgrades`] policy.
    pub retagged_batches: u64,
    /// Per-tenant-class slice of the traffic, indexed by class id.  A
    /// single entry in single-tenant deployments.
    pub per_class: Vec<ClassMetrics>,
}

/// Per-tenant-class serving metrics (one entry of
/// [`ServerMetrics::per_class`]).
#[derive(Debug, Default, Clone)]
pub struct ClassMetrics {
    /// Submissions through [`Server::submit_class`] (admitted or not).
    /// [`Server::submit`] bypasses this counter — the single-tenant
    /// fast path stays lock-free.
    pub submitted: u64,
    /// Requests answered.
    pub completed: u64,
    /// Submissions bounced by weighted admission
    /// ([`BatcherConfig::max_inflight`]).
    pub rejected: u64,
    /// Batches of this class retagged to a cheaper OP at execution.
    pub retagged_batches: u64,
    /// End-to-end latency over this class's requests.
    pub latency: LatencyHistogram,
}

impl ClassMetrics {
    /// Condense to plain numbers (see [`ServerMetrics::snapshot`]).
    pub fn snapshot(&self) -> ClassMetricsSnapshot {
        ClassMetricsSnapshot {
            submitted: self.submitted,
            completed: self.completed,
            rejected: self.rejected,
            retagged_batches: self.retagged_batches,
            latency: self.latency.summary(),
        }
    }
}

/// Plain-number condensation of one [`ClassMetrics`] entry.
#[derive(Debug, Clone, Default)]
pub struct ClassMetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub retagged_batches: u64,
    pub latency: LatencySummary,
}

impl ServerMetrics {
    fn new(n_ops: usize, classes: usize) -> Self {
        ServerMetrics {
            per_op_requests: vec![0; n_ops],
            per_op_latency: vec![LatencyHistogram::new(); n_ops],
            latency: LatencyHistogram::new(),
            queue_latency: LatencyHistogram::new(),
            per_class: vec![ClassMetrics::default(); classes.max(1)],
            ..Default::default()
        }
    }

    /// Mean executed batch size (0.0 before any batch completes).
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_size_sum as f64 / self.batches as f64
        }
    }

    /// Condense the histograms into a plain-number snapshot: overall and
    /// queue quantile summaries plus one [`OpMetricsSnapshot`] per
    /// `OpTable` index.  This is the single extraction point the serving
    /// report, the perf benches and the bench orchestrator share —
    /// quantile math lives in `util::stats`, not at every call site.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            completed: self.completed,
            batches: self.batches,
            mean_batch: self.mean_batch(),
            latency: self.latency.summary(),
            queue: self.queue_latency.summary(),
            per_op: self
                .per_op_requests
                .iter()
                .zip(&self.per_op_latency)
                .map(|(&requests, h)| OpMetricsSnapshot { requests, latency: h.summary() })
                .collect(),
            scale_ups: self.scale_ups,
            scale_downs: self.scale_downs,
            spawn_failures: self.spawn_failures,
            peak_workers: self.peak_workers,
            retagged_batches: self.retagged_batches,
            per_class: self.per_class.iter().map(ClassMetrics::snapshot).collect(),
        }
    }
}

/// Per-operating-point slice of a [`MetricsSnapshot`]: requests served
/// under this `OpTable` index and their end-to-end latency summary.
#[derive(Debug, Clone, Default)]
pub struct OpMetricsSnapshot {
    pub requests: u64,
    pub latency: LatencySummary,
}

/// Plain-number condensation of [`ServerMetrics`] (histograms reduced to
/// [`LatencySummary`] quantiles), from [`ServerMetrics::snapshot`].
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    pub completed: u64,
    pub batches: u64,
    pub mean_batch: f64,
    /// End-to-end latency over all requests.
    pub latency: LatencySummary,
    /// Submission-to-batch-formation latency over all requests.
    pub queue: LatencySummary,
    /// One entry per `OpTable` index, in table order.
    pub per_op: Vec<OpMetricsSnapshot>,
    pub scale_ups: u64,
    pub scale_downs: u64,
    pub spawn_failures: u64,
    pub peak_workers: usize,
    pub retagged_batches: u64,
    /// One entry per tenant class, in class-id (premium-first) order.
    pub per_class: Vec<ClassMetricsSnapshot>,
}

/// Bit of [`Shared::op_words`] marking the last switch as `Immediate`.
const OP_IMMEDIATE_FLAG: u64 = 1 << 63;

/// State shared between the batcher, workers, supervisor and handle.
struct Shared {
    /// Current `OpTable` index *per tenant class* (batches are stamped
    /// from their class's word at formation time), each packed with how
    /// the last switch was applied: bit 63 set = `Immediate`, clear =
    /// draining barrier.  One word per class so the retag policy reads
    /// a coherent (op, mode) pair — with two separate atomics a worker
    /// could pair a stale Immediate flag with a Drain switch's fresh
    /// index and retag a pre-barrier batch the barrier had promised the
    /// old OP.  The retag policy only fires after an Immediate switch —
    /// a Drain switch *guarantees* pre-barrier requests run under the
    /// old OP.  Single-tenant deployments hold exactly one word, so the
    /// pre-tenancy behavior is unchanged.
    op_words: Vec<AtomicU64>,
    /// Requests submitted but not yet answered (queue-depth signal).
    inflight: AtomicUsize,
    /// Workers that completed `prepare` and are serving (supervisor
    /// reservations included, see `spawn_worker`).
    live_workers: AtomicUsize,
    /// Next worker id handed to the factory.
    next_worker: AtomicUsize,
    /// Max submission-to-execution age (us) of the oldest request in
    /// any batch a worker started since the supervisor last sampled —
    /// the wait-time watermark (includes worker-channel backlog, not
    /// just time in the batcher).
    queue_watermark_us: AtomicU64,
    /// Explicit worker-count target installed by an external controller
    /// (the SLO autopilot); `usize::MAX` = unmanaged, i.e. the
    /// supervisor runs its own watermark heuristics.  While a target is
    /// set the supervisor converges the pool to it instead.
    pool_target: AtomicUsize,
    stop: AtomicBool,
}

/// [`Shared::pool_target`] sentinel: no external target installed.
const POOL_UNMANAGED: usize = usize::MAX;

impl Shared {
    fn new(first_worker: usize, classes: usize) -> Self {
        Shared {
            op_words: (0..classes.max(1)).map(|_| AtomicU64::new(0)).collect(),
            inflight: AtomicUsize::new(0),
            live_workers: AtomicUsize::new(0),
            next_worker: AtomicUsize::new(first_worker),
            queue_watermark_us: AtomicU64::new(0),
            pool_target: AtomicUsize::new(POOL_UNMANAGED),
            stop: AtomicBool::new(false),
        }
    }

    /// Publish an OP switch for one class: the new index + whether it
    /// was `Immediate`, in one store (see [`Shared::op_words`]).
    fn store_op(&self, class: usize, idx: usize, immediate: bool) {
        let word = idx as u64 | if immediate { OP_IMMEDIATE_FLAG } else { 0 };
        self.op_words[class].store(word, Ordering::Release);
    }

    /// One class's coherent (current OP index, last-switch-was-
    /// Immediate) pair.
    fn load_op(&self, class: usize) -> (usize, bool) {
        let word = self.op_words[class].load(Ordering::Acquire);
        ((word & !OP_IMMEDIATE_FLAG) as usize, word & OP_IMMEDIATE_FLAG != 0)
    }
}

/// Ingress-channel message: a request, or a draining switch barrier.
enum Ingress {
    Req(Request),
    /// Flush everything of `class` enqueued so far under its old OP,
    /// then apply `idx` to that class and ack.  The barrier is
    /// per-class: a premium switch never waits on another class's
    /// pending requests.
    Switch { class: usize, idx: usize, ack: mpsc::Sender<()> },
}

/// A formed batch, OP-tagged at formation time.  Single-class by
/// construction: the batcher never mixes tenant classes in one batch.
struct Batch {
    reqs: Vec<Request>,
    class: usize,
    op_idx: usize,
    seq: u64,
}

/// Worker-channel message: work, or an orderly retirement request.
enum WorkerMsg {
    Batch(Batch),
    Retire,
}

/// Everything a worker (or the supervisor spawning workers) needs;
/// cheap to clone per thread.
struct WorkerCtx<B, F> {
    factory: Arc<F>,
    ops: OpTable,
    rx: Arc<Mutex<mpsc::Receiver<WorkerMsg>>>,
    metrics: Arc<Mutex<ServerMetrics>>,
    shared: Arc<Shared>,
    /// See [`BatcherConfig::retag_downgrades`].
    retag_downgrades: bool,
    /// Per-class event label values (see [`class_labels`]).
    labels: Arc<Vec<Option<String>>>,
    _backend: PhantomData<fn() -> B>,
}

impl<B, F> Clone for WorkerCtx<B, F> {
    fn clone(&self) -> Self {
        WorkerCtx {
            factory: self.factory.clone(),
            ops: self.ops.clone(),
            rx: self.rx.clone(),
            metrics: self.metrics.clone(),
            shared: self.shared.clone(),
            retag_downgrades: self.retag_downgrades,
            labels: self.labels.clone(),
            _backend: PhantomData,
        }
    }
}

/// Handle to a running server; dropping it without
/// [`shutdown`](Server::shutdown) leaks the threads.
pub struct Server<B: Backend> {
    ingress: mpsc::Sender<Ingress>,
    shared: Arc<Shared>,
    ops: OpTable,
    metrics: Arc<Mutex<ServerMetrics>>,
    threads: Vec<std::thread::JoinHandle<()>>,
    /// Supervisor-spawned worker handles, joined at shutdown.
    scaled: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
    next_id: AtomicUsize,
    /// Normalized pool bounds (post-`start` invariants), kept so
    /// external pool targets can be clamped into the legal range.
    min_workers: usize,
    max_workers: usize,
    /// Per-class event/metric labels (see [`class_labels`]).
    labels: Arc<Vec<Option<String>>>,
    /// Weighted-admission knobs, copied out of the config.
    admit_fracs: Vec<f64>,
    max_inflight: usize,
    _backend: PhantomData<fn() -> B>,
}

impl<B: Backend + 'static> Server<B> {
    /// Start the batcher + initial workers (+ the scaling supervisor
    /// when `cfg` allows an elastic range).  `factory(w)` runs on
    /// worker `w`'s own thread to build its backend (backends need not
    /// be `Send`); each backend then `prepare`s the shared OP table
    /// before serving.  Blocks until every initial worker has reported
    /// its prepare outcome and fails if none came up — a server with
    /// zero live workers would otherwise accept requests and answer
    /// nothing.
    pub fn start<F>(factory: F, ops: OpTable, cfg: BatcherConfig) -> Result<Self>
    where
        F: Fn(usize) -> Result<B> + Send + Sync + 'static,
    {
        let mut cfg = cfg;
        // normalize the worker range: 0 bounds mean "same as workers"
        let initial = cfg.workers.max(1);
        cfg.min_workers = match cfg.min_workers {
            0 => initial,
            m => m.max(1),
        };
        cfg.max_workers = match cfg.max_workers {
            0 => initial,
            m => m.max(1),
        };
        // an explicitly set ceiling wins over a conflicting floor: never
        // run more workers than the caller capped the pool at
        cfg.min_workers = cfg.min_workers.min(cfg.max_workers);
        cfg.workers = initial.clamp(cfg.min_workers, cfg.max_workers);

        let n_classes = cfg.classes.max(1);
        let labels = Arc::new(class_labels(&cfg));
        let metrics = Arc::new(Mutex::new(ServerMetrics::new(ops.len(), n_classes)));
        let shared = Arc::new(Shared::new(cfg.workers, n_classes));

        let (ingress_tx, ingress_rx) = mpsc::channel::<Ingress>();
        let (batch_tx, batch_rx) = mpsc::channel::<WorkerMsg>();

        let ctx = WorkerCtx::<B, F> {
            factory: Arc::new(factory),
            ops: ops.clone(),
            rx: Arc::new(Mutex::new(batch_rx)),
            metrics: metrics.clone(),
            shared: shared.clone(),
            retag_downgrades: cfg.retag_downgrades,
            labels: labels.clone(),
            _backend: PhantomData,
        };

        let mut threads = Vec::new();

        // batcher thread: size- or deadline-triggered batch formation
        {
            let cfg2 = cfg.clone();
            let shared2 = shared.clone();
            let out = batch_tx.clone();
            threads.push(std::thread::spawn(move || {
                batcher_loop(ingress_rx, out, cfg2, shared2);
            }));
        }

        // initial workers; each reports construction/prepare outcome
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        for w in 0..cfg.workers {
            threads.push(spawn_worker(ctx.clone(), w, false, Some(ready_tx.clone())));
        }
        drop(ready_tx);

        let mut live = 0usize;
        let mut first_err: Option<anyhow::Error> = None;
        for _ in 0..cfg.workers {
            match ready_rx.recv() {
                Ok(Ok(())) => live += 1,
                Ok(Err(e)) => first_err = first_err.or(Some(e)),
                Err(_) => break, // worker died without reporting
            }
        }
        if live == 0 {
            shared.stop.store(true, Ordering::Release);
            drop(ingress_tx);
            drop(batch_tx);
            for t in threads.drain(..) {
                let _ = t.join();
            }
            return Err(first_err
                .unwrap_or_else(|| anyhow!("no inference worker came up"))
                .context("server start: every worker failed"));
        }
        metrics.lock().unwrap().peak_workers = live;

        // the scaling supervisor only exists when the pool is elastic
        let scaled = Arc::new(Mutex::new(Vec::new()));
        if cfg.max_workers > cfg.min_workers {
            let ctx2 = ctx.clone();
            let cfg2 = cfg.clone();
            let scaled2 = scaled.clone();
            threads.push(std::thread::spawn(move || {
                supervisor_loop(ctx2, batch_tx, cfg2, scaled2);
            }));
        } else {
            drop(batch_tx);
        }

        Ok(Server {
            ingress: ingress_tx,
            shared,
            ops,
            metrics,
            threads,
            scaled,
            next_id: AtomicUsize::new(0),
            min_workers: cfg.min_workers,
            max_workers: cfg.max_workers,
            labels,
            admit_fracs: cfg.admit_fracs.clone(),
            max_inflight: cfg.max_inflight,
            _backend: PhantomData,
        })
    }

    /// Submit one image; returns the response channel.  Single-tenant
    /// entry point: the request is class 0 and admission control is
    /// bypassed — exactly the pre-tenancy behavior.
    pub fn submit(&self, image: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        self.enqueue(0, image)
    }

    /// Submit one image under a tenant class, subject to weighted
    /// admission.  `Ok(None)` = rejected: total in-flight requests
    /// already fill the class's admission fraction of
    /// [`BatcherConfig::max_inflight`] (strictly-higher-priority
    /// classes' shares are out of its reach, so best-effort bounces
    /// first and premium only bounces when the deployment is
    /// hard-full).  With `max_inflight` 0 every submission is admitted.
    pub fn submit_class(
        &self,
        class: usize,
        image: Vec<f32>,
    ) -> Result<Option<mpsc::Receiver<Response>>> {
        let class = class.min(self.labels.len().saturating_sub(1));
        if self.max_inflight > 0 {
            let frac = self.admit_fracs.get(class).copied().unwrap_or(1.0);
            let cap = ((frac * self.max_inflight as f64).floor() as usize).max(1);
            if self.shared.inflight.load(Ordering::Acquire) >= cap {
                let mut m = self.metrics.lock().unwrap();
                m.per_class[class].submitted += 1;
                m.per_class[class].rejected += 1;
                return Ok(None);
            }
        }
        self.metrics.lock().unwrap().per_class[class].submitted += 1;
        self.enqueue(class, image).map(Some)
    }

    fn enqueue(&self, class: usize, image: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        let (tx, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) as u64;
        self.shared.inflight.fetch_add(1, Ordering::AcqRel);
        let sent = self.ingress.send(Ingress::Req(Request {
            id,
            class,
            image,
            enqueued: Instant::now(),
            resp: tx,
        }));
        if sent.is_err() {
            self.shared.inflight.fetch_sub(1, Ordering::AcqRel);
            return Err(anyhow!("server stopped"));
        }
        Ok(rx)
    }

    /// Switch the serving operating point immediately (a single atomic
    /// store; batches formed from here on are tagged with `idx`).
    /// Class 0 — the whole deployment when single-tenant.
    pub fn set_operating_point(&self, idx: usize) {
        self.set_class_operating_point(0, idx);
    }

    /// [`set_operating_point`](Self::set_operating_point) for one
    /// tenant class: only that class's batches change OP.
    pub fn set_class_operating_point(&self, class: usize, idx: usize) {
        assert!(idx < self.ops.len());
        self.shared.store_op(class, idx, true);
    }

    /// Switch the serving operating point under an explicit
    /// [`SwitchMode`].  `Immediate` is the atomic store of
    /// [`set_operating_point`](Self::set_operating_point).  `Drain`
    /// installs a barrier in the batcher and blocks until it is
    /// applied: every request submitted before this call completes
    /// under the old OP, every request submitted after it returns runs
    /// under the new one, and no batch spans the switch.  Class 0.
    pub fn set_operating_point_with(&self, idx: usize, mode: SwitchMode) -> Result<()> {
        self.set_class_operating_point_with(0, idx, mode)
    }

    /// [`set_operating_point_with`](Self::set_operating_point_with)
    /// for one tenant class.  The `Drain` barrier is per-class: it
    /// flushes and re-tags only `class`'s pending requests, so a
    /// premium switch never stalls behind a best-effort backlog.
    pub fn set_class_operating_point_with(
        &self,
        class: usize,
        idx: usize,
        mode: SwitchMode,
    ) -> Result<()> {
        assert!(idx < self.ops.len());
        match mode {
            SwitchMode::Immediate => {
                self.set_class_operating_point(class, idx);
                Ok(())
            }
            SwitchMode::Drain => {
                let (ack_tx, ack_rx) = mpsc::channel();
                self.ingress
                    .send(Ingress::Switch { class, idx, ack: ack_tx })
                    .map_err(|_| anyhow!("server stopped"))?;
                ack_rx
                    .recv()
                    .map_err(|_| anyhow!("batcher exited before applying the switch"))?;
                Ok(())
            }
        }
    }

    /// Current `OpTable` index batches are being tagged with (class 0).
    pub fn operating_point(&self) -> usize {
        self.shared.load_op(0).0
    }

    /// Current `OpTable` index one tenant class's batches are tagged
    /// with.
    pub fn class_operating_point(&self, class: usize) -> usize {
        self.shared.load_op(class).0
    }

    /// The served operating points, in table order.
    pub fn ops(&self) -> &[OperatingPoint] {
        self.ops.ops()
    }

    /// The shared operating-point table.
    pub fn op_table(&self) -> &OpTable {
        &self.ops
    }

    /// Workers currently serving (floor <= n <= ceiling when elastic).
    pub fn live_workers(&self) -> usize {
        self.shared.live_workers.load(Ordering::Acquire)
    }

    /// Requests submitted but not yet answered.
    pub fn inflight(&self) -> usize {
        self.shared.inflight.load(Ordering::Acquire)
    }

    /// Install an explicit worker-count target (clamped into the pool's
    /// `[min_workers, max_workers]` range) and return the clamped
    /// value.  While a target is set, the scaling supervisor converges
    /// the pool to it instead of running its own queue-depth
    /// heuristics — this is the autopilot's capacity actuator.  On a
    /// fixed pool (no supervisor) the target is recorded but inert,
    /// and the clamp collapses it to the fixed size.
    pub fn set_pool_target(&self, workers: usize) -> usize {
        let clamped = workers.clamp(self.min_workers, self.max_workers);
        self.shared.pool_target.store(clamped, Ordering::Release);
        clamped
    }

    /// Remove any explicit pool target: the supervisor resumes its
    /// watermark-driven scaling on its next tick.
    pub fn clear_pool_target(&self) {
        self.shared.pool_target.store(POOL_UNMANAGED, Ordering::Release);
    }

    /// The explicit pool target currently installed, if any.
    pub fn pool_target(&self) -> Option<usize> {
        match self.shared.pool_target.load(Ordering::Acquire) {
            POOL_UNMANAGED => None,
            n => Some(n),
        }
    }

    /// Snapshot of the aggregate metrics.
    pub fn metrics(&self) -> ServerMetrics {
        self.metrics.lock().unwrap().clone()
    }

    /// A scrape-time collector for [`crate::obs::Registry::register`]:
    /// it reads [`ServerMetrics::snapshot`] and the shared gauges when
    /// the endpoint is scraped, so the exposition, the live dashboard
    /// and the final serving report all condense the *same* histograms
    /// — nothing is double-counted and the hot path pays nothing.
    pub fn metrics_collector(&self) -> impl Fn() -> Vec<MetricFamily> + Send + Sync + 'static {
        let metrics = self.metrics.clone();
        let shared = self.shared.clone();
        let op_names: Vec<String> = self.ops.ops().iter().map(|op| op.name.clone()).collect();
        let labels = self.labels.clone();
        move || {
            let snap = metrics.lock().unwrap().snapshot();
            let mut fams = vec![
                MetricFamily::new(
                    "qos_nets_requests_completed_total",
                    "Requests answered by the batching server.",
                    Kind::Counter,
                    vec![Sample::plain(snap.completed as f64)],
                ),
                MetricFamily::new(
                    "qos_nets_batches_total",
                    "Batches executed by the worker pool.",
                    Kind::Counter,
                    vec![Sample::plain(snap.batches as f64)],
                ),
                MetricFamily::new(
                    "qos_nets_batches_retagged_total",
                    "Batches retagged to a cheaper OP at execution time.",
                    Kind::Counter,
                    vec![Sample::plain(snap.retagged_batches as f64)],
                ),
                MetricFamily::new(
                    "qos_nets_inflight",
                    "Requests submitted but not yet answered.",
                    Kind::Gauge,
                    vec![Sample::plain(shared.inflight.load(Ordering::Acquire) as f64)],
                ),
                MetricFamily::new(
                    "qos_nets_workers",
                    "Live inference workers in the elastic pool.",
                    Kind::Gauge,
                    vec![Sample::plain(shared.live_workers.load(Ordering::Acquire) as f64)],
                ),
            ];
            fams.extend(summary_families(
                "qos_nets_latency_us",
                "End-to-end request latency, microseconds.",
                &[],
                &snap.latency,
            ));
            fams.extend(summary_families(
                "qos_nets_queue_latency_us",
                "Submission-to-batch-formation latency, microseconds.",
                &[],
                &snap.queue,
            ));
            let mut op_requests = Vec::with_capacity(snap.per_op.len());
            for (i, per_op) in snap.per_op.iter().enumerate() {
                let name = op_names.get(i).map(String::as_str).unwrap_or("?");
                op_requests.push(Sample::with(&[("op", name)], per_op.requests as f64));
                fams.extend(summary_families(
                    "qos_nets_op_latency_us",
                    "End-to-end latency per operating point, microseconds.",
                    &[("op", name)],
                    &per_op.latency,
                ));
            }
            fams.push(MetricFamily::new(
                "qos_nets_op_requests_total",
                "Requests served per operating point.",
                Kind::Counter,
                op_requests,
            ));
            // per-tenant-class families only exist in multi-tenant
            // deployments — a single-tenant scrape is byte-identical
            // to the pre-tenancy exposition
            if labels.len() > 1 {
                let mut completed = Vec::with_capacity(labels.len());
                let mut rejected = Vec::with_capacity(labels.len());
                for (c, pc) in snap.per_class.iter().enumerate() {
                    let name = labels.get(c).and_then(|l| l.as_deref()).unwrap_or("?");
                    completed.push(Sample::with(&[("class", name)], pc.completed as f64));
                    rejected.push(Sample::with(&[("class", name)], pc.rejected as f64));
                    fams.extend(summary_families(
                        "qos_nets_class_latency_us",
                        "End-to-end latency per tenant class, microseconds.",
                        &[("class", name)],
                        &pc.latency,
                    ));
                }
                fams.push(MetricFamily::new(
                    "qos_nets_class_requests_total",
                    "Requests answered per tenant class.",
                    Kind::Counter,
                    completed,
                ));
                fams.push(MetricFamily::new(
                    "qos_nets_class_rejected_total",
                    "Submissions bounced by weighted admission, per tenant class.",
                    Kind::Counter,
                    rejected,
                ));
            }
            fams
        }
    }

    /// Drain and stop; joins all threads (including supervisor-spawned
    /// workers) and returns the final metrics.
    pub fn shutdown(mut self) -> ServerMetrics {
        self.shared.stop.store(true, Ordering::Release);
        drop(self.ingress);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        // the supervisor has exited by now, so no new handles appear
        let mut scaled = self.scaled.lock().unwrap();
        for t in scaled.drain(..) {
            let _ = t.join();
        }
        drop(scaled);
        self.metrics.lock().unwrap().clone()
    }
}

impl Server<NativeBackend> {
    /// Convenience: serve the native bit-exact LUT engine (one per
    /// worker) over a shared operating-point table.
    pub fn start_native(
        graph: Arc<Graph>,
        db: Arc<MulDb>,
        ops: OpTable,
        cfg: BatcherConfig,
    ) -> Result<Self> {
        Server::start(
            move |_w| Ok(NativeBackend::new(graph.clone(), db.clone())),
            ops,
            cfg,
        )
    }
}

/// Spawn one worker thread.  `reserved` marks a supervisor spawn whose
/// `live_workers` slot was incremented up front (to keep scaling
/// decisions race-free); such a worker releases the slot on any exit,
/// including init failure.  Initial workers instead claim their slot
/// after a successful `prepare` and report through `ready`.
fn spawn_worker<B, F>(
    ctx: WorkerCtx<B, F>,
    w: usize,
    reserved: bool,
    ready: Option<mpsc::Sender<Result<()>>>,
) -> std::thread::JoinHandle<()>
where
    B: Backend + 'static,
    F: Fn(usize) -> Result<B> + Send + Sync + 'static,
{
    std::thread::spawn(move || {
        let built = (*ctx.factory)(w).and_then(|mut b| {
            b.prepare(ctx.ops.ops())?;
            Ok(b)
        });
        match built {
            Ok(mut backend) => {
                if !reserved {
                    ctx.shared.live_workers.fetch_add(1, Ordering::AcqRel);
                }
                if let Some(tx) = &ready {
                    let _ = tx.send(Ok(()));
                }
                worker_loop(&mut backend, &ctx);
                ctx.shared.live_workers.fetch_sub(1, Ordering::AcqRel);
            }
            Err(e) => {
                obs::log!(Error, "worker {w}: backend init failed: {e:#}");
                if reserved {
                    let was = ctx.shared.live_workers.fetch_sub(1, Ordering::AcqRel);
                    ctx.metrics.lock().unwrap().spawn_failures += 1;
                    obs::publish(ObsEvent::ScaleAction {
                        action: "spawn_failure".to_string(),
                        workers: was.saturating_sub(1),
                    });
                }
                if let Some(tx) = ready {
                    let _ = tx.send(Err(e));
                }
            }
        }
    })
}

fn worker_loop<B, F>(backend: &mut B, ctx: &WorkerCtx<B, F>)
where
    B: Backend,
{
    loop {
        let msg = {
            let guard = ctx.rx.lock().unwrap();
            guard.recv()
        };
        let Ok(msg) = msg else { break };
        let batch = match msg {
            WorkerMsg::Batch(b) => b,
            WorkerMsg::Retire => break,
        };
        let b = batch.reqs.len();
        if b == 0 {
            continue;
        }
        let mut op_idx = batch.op_idx;
        // Immediate-downgrade policy: a queued batch about to execute
        // under a *more expensive* OP than the current one is retagged
        // to the cheaper rung, so a deep backlog honors the power
        // budget instead of finishing at the old power.  Only fires
        // after an *Immediate* switch — a Drain barrier guarantees
        // pre-switch batches the old OP, and upgrades never retag
        // (strict formation-time tagging is kept in that direction).
        // The batch stays uniform either way.
        let mut retagged = false;
        if ctx.retag_downgrades {
            // one load of the batch's own class word: the (op, mode)
            // pair is coherent, so a Drain switch landing between two
            // separate reads can never be misattributed to an earlier
            // Immediate switch
            let (cur, immediate) = ctx.shared.load_op(batch.class);
            if immediate
                && cur != op_idx
                && ctx.ops.get(cur).relative_power < ctx.ops.get(op_idx).relative_power
            {
                op_idx = cur;
                retagged = true;
            }
        }
        let started = Instant::now();
        // wait-time watermark for the supervisor: submission-to-execution
        // age of the batch's oldest request, which keeps growing with the
        // worker-channel backlog (unlike time-in-batcher, capped at
        // max_wait)
        let oldest_us = started
            .saturating_duration_since(batch.reqs[0].enqueued)
            .as_micros() as u64;
        ctx.shared
            .queue_watermark_us
            .fetch_max(oldest_us, Ordering::AcqRel);
        let elems = batch.reqs[0].image.len();
        let mut images = Vec::with_capacity(b * elems);
        for r in &batch.reqs {
            images.extend_from_slice(&r.image);
        }
        let logits = match backend.forward_class(batch.class, op_idx, &images, b) {
            Ok(l) => l,
            Err(e) => {
                obs::log!(Error, "{} backend: dropping batch of {b}: {e:#}", backend.name());
                ctx.shared.inflight.fetch_sub(b, Ordering::AcqRel);
                continue;
            }
        };
        let classes = logits.len() / b;
        let done = Instant::now();
        let times: Vec<(u64, u64)> = batch
            .reqs
            .iter()
            .map(|r| {
                (
                    started.duration_since(r.enqueued).as_micros() as u64,
                    done.duration_since(r.enqueued).as_micros() as u64,
                )
            })
            .collect();
        // record metrics in one short critical section, then send the
        // responses with the lock released — the metrics mutex must not
        // serialize the (elastic) worker pool on allocation + channel work
        {
            let mut m = ctx.metrics.lock().unwrap();
            m.batches += 1;
            m.batch_size_sum += b as u64;
            if retagged {
                m.retagged_batches += 1;
                m.per_class[batch.class].retagged_batches += 1;
            }
            for &(queue_us, total_us) in &times {
                m.completed += 1;
                m.per_op_requests[op_idx] += 1;
                m.latency.record_us(total_us);
                m.queue_latency.record_us(queue_us);
                m.per_op_latency[op_idx].record_us(total_us);
                m.per_class[batch.class].completed += 1;
                m.per_class[batch.class].latency.record_us(total_us);
            }
        }
        if obs::recording() {
            obs::publish(ObsEvent::BatchDone {
                batch: batch.seq,
                op: op_idx,
                size: b,
                latency_us: times[0].1,
                retagged,
                class: ctx.labels.get(batch.class).cloned().flatten(),
            });
        }
        for ((i, r), &(queue_us, total_us)) in batch.reqs.into_iter().enumerate().zip(&times) {
            let _ = r.resp.send(Response {
                id: r.id,
                class: batch.class,
                logits: logits[i * classes..(i + 1) * classes].to_vec(),
                op_index: op_idx,
                batch_seq: batch.seq,
                queue_us,
                total_us,
            });
        }
        ctx.shared.inflight.fetch_sub(b, Ordering::AcqRel);
    }
}

/// Flush one class's `pending` as one OP-tagged batch.
fn flush_batch(
    class: usize,
    label: &Option<String>,
    pending: &mut Vec<Request>,
    out: &mpsc::Sender<WorkerMsg>,
    shared: &Shared,
    seq: &mut u64,
) {
    if pending.is_empty() {
        return;
    }
    let batch = Batch {
        reqs: std::mem::take(pending),
        class,
        op_idx: shared.load_op(class).0,
        seq: *seq,
    };
    *seq += 1;
    if obs::recording() {
        obs::publish(ObsEvent::BatchFormed {
            batch: batch.seq,
            op: batch.op_idx,
            size: batch.reqs.len(),
            class: label.clone(),
        });
    }
    let _ = out.send(WorkerMsg::Batch(batch));
}

/// The batcher keeps one pending queue + flush deadline per tenant
/// class (a batch never mixes classes) and walks classes in id order —
/// premium-first — wherever several are due at once.  Single-tenant
/// this degenerates to the pre-tenancy single queue.
fn batcher_loop(
    ingress: mpsc::Receiver<Ingress>,
    out: mpsc::Sender<WorkerMsg>,
    cfg: BatcherConfig,
    shared: Arc<Shared>,
) {
    let n_classes = cfg.classes.max(1);
    let labels = class_labels(&cfg);
    let mut pending: Vec<Vec<Request>> = (0..n_classes).map(|_| Vec::new()).collect();
    let mut deadlines: Vec<Option<Instant>> = vec![None; n_classes];
    let mut seq: u64 = 0;
    let mut flush = |c: usize, pending: &mut Vec<Vec<Request>>, seq: &mut u64| {
        flush_batch(c, &labels[c], &mut pending[c], &out, &shared, seq);
    };
    loop {
        if shared.stop.load(Ordering::Acquire) {
            // stop requested: drain whatever is already queued, flush the
            // final partial batches and exit promptly (shutdown no longer
            // relies solely on channel disconnect)
            while let Ok(msg) = ingress.try_recv() {
                match msg {
                    Ingress::Req(req) => {
                        let c = req.class.min(n_classes - 1);
                        pending[c].push(req);
                        if pending[c].len() >= cfg.max_batch {
                            flush(c, &mut pending, &mut seq);
                        }
                    }
                    Ingress::Switch { class, idx, ack } => {
                        let c = class.min(n_classes - 1);
                        flush(c, &mut pending, &mut seq);
                        shared.store_op(c, idx, false);
                        let _ = ack.send(());
                    }
                }
            }
            for c in 0..n_classes {
                flush(c, &mut pending, &mut seq);
            }
            break;
        }
        let timeout = match deadlines.iter().flatten().min() {
            Some(d) => d.saturating_duration_since(Instant::now()),
            None => Duration::from_millis(50),
        };
        match ingress.recv_timeout(timeout) {
            Ok(Ingress::Req(req)) => {
                let c = req.class.min(n_classes - 1);
                if pending[c].is_empty() {
                    deadlines[c] = Some(Instant::now() + cfg.max_wait);
                }
                pending[c].push(req);
                if pending[c].len() >= cfg.max_batch {
                    flush(c, &mut pending, &mut seq);
                    deadlines[c] = None;
                }
            }
            Ok(Ingress::Switch { class, idx, ack }) => {
                // the drain barrier, scoped to one class: everything of
                // that class enqueued before the switch leaves as
                // batches tagged with its old OP, then the new index
                // takes effect (and the retag policy is disarmed —
                // Drain promises those batches the old OP).  Other
                // classes' queues are untouched, so a premium switch
                // never stalls behind a best-effort backlog.
                let c = class.min(n_classes - 1);
                flush(c, &mut pending, &mut seq);
                deadlines[c] = None;
                shared.store_op(c, idx, false);
                let _ = ack.send(());
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                let now = Instant::now();
                for c in 0..n_classes {
                    if !pending[c].is_empty() && deadlines[c].is_none_or(|d| d <= now) {
                        flush(c, &mut pending, &mut seq);
                        deadlines[c] = None;
                    }
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                for c in 0..n_classes {
                    flush(c, &mut pending, &mut seq);
                }
                break;
            }
        }
    }
}

/// How many workers one pressured supervisor tick may spawn: one per
/// full multiple of the queue-depth threshold currently in flight
/// (scale-up batching — a queue three thresholds deep gets three
/// workers at once instead of one per tick), at least one when there is
/// any headroom (wait-time pressure alone still spawns a single
/// worker), and never past the `max_workers` ceiling.
pub fn scale_up_count(
    inflight: usize,
    depth_threshold: usize,
    live: usize,
    max_workers: usize,
) -> usize {
    let headroom = max_workers.saturating_sub(live);
    (inflight / depth_threshold.max(1)).max(1).min(headroom)
}

/// Track a supervisor-spawned worker handle, pruning handles whose
/// threads already exited (dropping a finished handle just detaches
/// it) so a persistently failing factory cannot grow the vec forever.
fn push_handle(
    handles: &Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
    handle: std::thread::JoinHandle<()>,
) {
    let mut hs = handles.lock().unwrap();
    hs.retain(|h| !h.is_finished());
    hs.push(handle);
}

/// The scaling supervisor: samples queue depth (in-flight requests per
/// live worker) and the wait-time watermark (submission-to-execution
/// age recorded by workers) every `scale_interval`, spawning
/// [`scale_up_count`] workers (scale-up batching: one per full
/// depth-threshold multiple in the queue) after `scale_up_after`
/// consecutive pressured ticks and retiring one worker
/// after `scale_down_after` consecutive idle ticks; a pool below
/// `min_workers` (partial init failure, worker death) is healed back
/// to the floor unconditionally.  Spawns reserve their `live_workers`
/// slot before the thread starts so decisions never overshoot
/// `max_workers`; retirements go through the work queue, so a worker
/// only leaves once everything queued ahead is served.
fn supervisor_loop<B, F>(
    ctx: WorkerCtx<B, F>,
    batch_tx: mpsc::Sender<WorkerMsg>,
    cfg: BatcherConfig,
    handles: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
) where
    B: Backend + 'static,
    F: Fn(usize) -> Result<B> + Send + Sync + 'static,
{
    let mut up_streak = 0u32;
    let mut idle_streak = 0u32;
    loop {
        std::thread::sleep(cfg.scale_interval);
        if ctx.shared.stop.load(Ordering::Acquire) {
            break;
        }
        let live = ctx.shared.live_workers.load(Ordering::Acquire);
        let inflight = ctx.shared.inflight.load(Ordering::Acquire);
        let wait_us = ctx.shared.queue_watermark_us.swap(0, Ordering::AcqRel);
        {
            let mut m = ctx.metrics.lock().unwrap();
            m.peak_workers = m.peak_workers.max(live);
        }
        // heal to the floor first: partial init failure or worker death
        // must not leave an elastic pool below min_workers (retried once
        // per tick while the factory keeps failing)
        if live < cfg.min_workers {
            ctx.shared.live_workers.fetch_add(1, Ordering::AcqRel);
            let w = ctx.shared.next_worker.fetch_add(1, Ordering::AcqRel);
            let handle = spawn_worker(ctx.clone(), w, true, None);
            push_handle(&handles, handle);
            ctx.metrics.lock().unwrap().scale_ups += 1;
            obs::publish(ObsEvent::ScaleAction { action: "up".to_string(), workers: live + 1 });
            continue;
        }
        // an explicit pool target (installed by the autopilot via
        // `set_pool_target`) overrides the watermark heuristics: spawn
        // straight to the target, retire one worker per tick above it
        // (gentle shrink — FIFO Retire tokens queue behind in-flight
        // work, and one per tick keeps a transient target from
        // draining the pool before the controller reconsiders)
        let target = ctx.shared.pool_target.load(Ordering::Acquire);
        if target != POOL_UNMANAGED {
            up_streak = 0;
            idle_streak = 0;
            let target = target.clamp(cfg.min_workers, cfg.max_workers);
            if live < target {
                let n = target - live;
                for _ in 0..n {
                    ctx.shared.live_workers.fetch_add(1, Ordering::AcqRel);
                    let w = ctx.shared.next_worker.fetch_add(1, Ordering::AcqRel);
                    let handle = spawn_worker(ctx.clone(), w, true, None);
                    push_handle(&handles, handle);
                }
                {
                    let mut m = ctx.metrics.lock().unwrap();
                    m.scale_ups += n as u64;
                    m.peak_workers = m.peak_workers.max(target);
                }
                obs::publish(ObsEvent::ScaleAction { action: "up".to_string(), workers: target });
            } else if live > target {
                let _ = batch_tx.send(WorkerMsg::Retire);
                ctx.metrics.lock().unwrap().scale_downs += 1;
                obs::publish(ObsEvent::ScaleAction {
                    action: "down".to_string(),
                    workers: live - 1,
                });
            }
            continue;
        }
        // the watermark includes the intentional max_wait batching
        // delay, so the trigger is measured beyond it — otherwise
        // max_wait >= scale_up_wait would pin the pool at the ceiling
        // under trivial load
        let wait_thresh = (cfg.scale_up_wait + cfg.max_wait).as_micros() as u64;
        // inflight counts executing requests too, so the depth threshold
        // is at least one full batch per worker — a single slow
        // in-progress batch must not read as queue pressure
        let depth_thresh = cfg
            .scale_up_queue
            .max(cfg.max_batch)
            .saturating_mul(live.max(1));
        let pressured = inflight > depth_thresh || wait_us > wait_thresh;
        // idle: no meaningful backlog — a steady trickle must not pin a
        // post-burst pool at its peak, so "idle" tolerates a handful of
        // in-flight requests and deadline-flushed (sub-threshold) waits
        let idle = inflight <= live / 2 && wait_us <= wait_thresh;
        if pressured {
            up_streak += 1;
            idle_streak = 0;
        } else if idle {
            idle_streak += 1;
            up_streak = 0;
        } else {
            up_streak = 0;
            idle_streak = 0;
        }
        if pressured && up_streak >= cfg.scale_up_after && live < cfg.max_workers {
            up_streak = 0;
            // scale-up batching: one worker per full depth-threshold
            // multiple in the queue, so a deep burst reaches the
            // ceiling in a single tick instead of one worker per tick
            let n = scale_up_count(inflight, depth_thresh, live, cfg.max_workers);
            for _ in 0..n {
                // reserve the slot before the thread exists (see spawn_worker)
                ctx.shared.live_workers.fetch_add(1, Ordering::AcqRel);
                let w = ctx.shared.next_worker.fetch_add(1, Ordering::AcqRel);
                let handle = spawn_worker(ctx.clone(), w, true, None);
                push_handle(&handles, handle);
            }
            {
                let mut m = ctx.metrics.lock().unwrap();
                m.scale_ups += n as u64;
                m.peak_workers = m.peak_workers.max(live + n);
            }
            obs::publish(ObsEvent::ScaleAction { action: "up".to_string(), workers: live + n });
        }
        if idle && idle_streak >= cfg.scale_down_after && live > cfg.min_workers {
            idle_streak = 0;
            // FIFO retirement: the token queues behind any in-flight
            // work, so retiring never drops batches
            let _ = batch_tx.send(WorkerMsg::Retire);
            ctx.metrics.lock().unwrap().scale_downs += 1;
            obs::publish(ObsEvent::ScaleAction { action: "down".to_string(), workers: live - 1 });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(val: f32) -> (Request, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        (
            Request {
                id: 0,
                class: 0,
                image: vec![val, 0.0],
                enqueued: Instant::now(),
                resp: tx,
            },
            rx,
        )
    }

    fn spawn_batcher(
        cfg: BatcherConfig,
    ) -> (
        mpsc::Sender<Ingress>,
        mpsc::Receiver<WorkerMsg>,
        Arc<Shared>,
        std::thread::JoinHandle<()>,
    ) {
        let (in_tx, in_rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::channel();
        let shared = Arc::new(Shared::new(0, cfg.classes.max(1)));
        let shared2 = shared.clone();
        let h = std::thread::spawn(move || batcher_loop(in_rx, out_tx, cfg, shared2));
        (in_tx, out_rx, shared, h)
    }

    fn recv_batch(rx: &mpsc::Receiver<WorkerMsg>) -> Batch {
        loop {
            match rx.recv_timeout(Duration::from_secs(5)).unwrap() {
                WorkerMsg::Batch(b) => return b,
                WorkerMsg::Retire => continue,
            }
        }
    }

    #[test]
    fn metrics_snapshot_condenses_histograms_per_op() {
        let mut m = ServerMetrics::new(2, 1);
        m.completed = 3;
        m.batches = 2;
        m.batch_size_sum = 3;
        for us in [100u64, 200, 4000] {
            m.latency.record_us(us);
        }
        m.queue_latency.record_us(50);
        m.per_op_requests[0] = 2;
        m.per_op_requests[1] = 1;
        m.per_op_latency[0].record_us(100);
        m.per_op_latency[0].record_us(200);
        m.per_op_latency[1].record_us(4000);
        let s = m.snapshot();
        assert_eq!(s.completed, 3);
        assert_eq!(s.per_op.len(), 2);
        assert_eq!(s.per_op[0].requests, 2);
        assert_eq!(s.per_op[0].latency.count, 2);
        assert_eq!(s.per_op[1].latency.max_us, 4000);
        assert!(s.latency.p99_us >= 4000, "p99 {}", s.latency.p99_us);
        assert_eq!(s.queue.count, 1);
        assert!((s.mean_batch - 1.5).abs() < 1e-12);
    }

    #[test]
    fn batcher_flushes_when_size_reached() {
        // deadline far away: only the size trigger can flush
        let (in_tx, out_rx, _shared, h) = spawn_batcher(BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_secs(30),
            workers: 1,
            ..BatcherConfig::default()
        });
        let mut resp_rxs = Vec::new();
        for i in 0..4 {
            let (r, rx) = req(i as f32);
            resp_rxs.push(rx);
            in_tx.send(Ingress::Req(r)).unwrap();
        }
        let batch = recv_batch(&out_rx);
        assert_eq!(batch.reqs.len(), 4);
        drop(in_tx);
        h.join().unwrap();
    }

    #[test]
    fn batcher_flushes_partial_batch_at_deadline() {
        // size trigger unreachable: only the deadline can flush
        let (in_tx, out_rx, _shared, h) = spawn_batcher(BatcherConfig {
            max_batch: 100,
            max_wait: Duration::from_millis(20),
            workers: 1,
            ..BatcherConfig::default()
        });
        let mut resp_rxs = Vec::new();
        for i in 0..3 {
            let (r, rx) = req(i as f32);
            resp_rxs.push(rx);
            in_tx.send(Ingress::Req(r)).unwrap();
        }
        let t0 = Instant::now();
        let batch = recv_batch(&out_rx);
        assert_eq!(batch.reqs.len(), 3);
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "deadline flush took {:?}",
            t0.elapsed()
        );
        drop(in_tx);
        h.join().unwrap();
    }

    #[test]
    fn batcher_exits_promptly_when_stopped_and_drained() {
        let (in_tx, out_rx, shared, h) = spawn_batcher(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            workers: 1,
            ..BatcherConfig::default()
        });
        let (r, _resp_rx) = req(1.0);
        in_tx.send(Ingress::Req(r)).unwrap();
        shared.stop.store(true, Ordering::Release);
        let t0 = Instant::now();
        // the ingress sender stays alive: only the stop flag can end the
        // loop (this is the dead-branch regression test)
        let batches: Vec<WorkerMsg> = out_rx.iter().collect();
        h.join().unwrap();
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "stop took {:?}",
            t0.elapsed()
        );
        let total: usize = batches
            .iter()
            .map(|m| match m {
                WorkerMsg::Batch(b) => b.reqs.len(),
                WorkerMsg::Retire => 0,
            })
            .sum();
        assert_eq!(total, 1, "pending request must be flushed, not dropped");
        drop(in_tx);
    }

    #[test]
    fn batcher_switch_barrier_flushes_old_op_then_applies_new() {
        let (in_tx, out_rx, shared, h) = spawn_batcher(BatcherConfig {
            max_batch: 100,
            max_wait: Duration::from_secs(30), // only the barrier can flush
            workers: 1,
            ..BatcherConfig::default()
        });
        let mut resp_rxs = Vec::new();
        for i in 0..3 {
            let (r, rx) = req(i as f32);
            resp_rxs.push(rx);
            in_tx.send(Ingress::Req(r)).unwrap();
        }
        let (ack_tx, ack_rx) = mpsc::channel();
        in_tx
            .send(Ingress::Switch { class: 0, idx: 1, ack: ack_tx })
            .unwrap();
        ack_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        // the pre-switch batch left tagged with the old OP...
        let batch = recv_batch(&out_rx);
        assert_eq!(batch.reqs.len(), 3);
        assert_eq!(batch.op_idx, 0);
        // ...and the new OP is in effect for later batches
        assert_eq!(shared.load_op(0).0, 1);
        let (r, _rx) = req(9.0);
        in_tx.send(Ingress::Req(r)).unwrap();
        let (ack_tx, ack_rx) = mpsc::channel();
        in_tx
            .send(Ingress::Switch { class: 0, idx: 0, ack: ack_tx })
            .unwrap();
        ack_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let batch = recv_batch(&out_rx);
        assert_eq!(batch.reqs.len(), 1);
        assert_eq!(batch.op_idx, 1);
        drop(in_tx);
        h.join().unwrap();
    }

    #[test]
    fn multi_class_barrier_drains_only_its_own_class() {
        let (in_tx, out_rx, shared, h) = spawn_batcher(BatcherConfig {
            max_batch: 100,
            max_wait: Duration::from_secs(30), // only barriers can flush
            workers: 1,
            classes: 2,
            ..BatcherConfig::default()
        });
        let mut resp_rxs = Vec::new();
        // one pending request per class
        for class in [0usize, 1] {
            let (mut r, rx) = req(class as f32);
            r.class = class;
            resp_rxs.push(rx);
            in_tx.send(Ingress::Req(r)).unwrap();
        }
        // a best-effort (class 1) drain barrier must not flush premium
        let (ack_tx, ack_rx) = mpsc::channel();
        in_tx
            .send(Ingress::Switch { class: 1, idx: 2, ack: ack_tx })
            .unwrap();
        ack_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let batch = recv_batch(&out_rx);
        assert_eq!(batch.class, 1);
        assert_eq!(batch.reqs.len(), 1);
        assert_eq!(batch.op_idx, 0, "pre-barrier batch keeps the old OP");
        assert_eq!(shared.load_op(1).0, 2);
        assert_eq!(shared.load_op(0).0, 0, "premium's word is untouched");
        // premium is still queued; its own barrier flushes it
        let (ack_tx, ack_rx) = mpsc::channel();
        in_tx
            .send(Ingress::Switch { class: 0, idx: 1, ack: ack_tx })
            .unwrap();
        ack_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let batch = recv_batch(&out_rx);
        assert_eq!(batch.class, 0);
        assert_eq!(batch.reqs.len(), 1);
        assert_eq!(shared.load_op(0).0, 1);
        drop(in_tx);
        h.join().unwrap();
    }
}
