//! Blocked LUT matmul — the L3 hot loop (native mirror of the L1 kernel),
//! behind a runtime-selected [`LutKernel`] dispatch.
//!
//! Computes  out[m, n] = sum_k lut[a[m, k], w[k, n]]  over u8 codes held
//! in i32, exactly like the Pallas kernel / ref.py oracle.
//!
//! Layout strategy (see EXPERIMENTS.md §Perf for the measured iteration):
//!   * the LUT is transposed once per multiplier to w-major order
//!     (`wlut[w * 256 + a]`), so for a fixed weight code the 256-entry
//!     row is one KiB of hot cache;
//!   * A is transposed to (K, M) so the inner m-loop reads contiguous
//!     indices; W is transposed to (N, K) so each output column walks a
//!     contiguous code row;
//!   * M is tiled so the A^T tile stays cache-resident while all N
//!     columns sweep over it.
//!
//! Three kernels implement that strategy (all bit-identical — integer
//! accumulation is exact, so every kernel must agree with the naive
//! oracle, pinned in `rust/tests/kernels.rs`):
//!   * [`ScalarKernel`] — the portable 2-way-k-unrolled baseline;
//!   * [`Avx2Kernel`] — `std::arch` AVX2 `vpgatherdd` over the w-major
//!     KiB LUT rows (x86_64 only, constructed only when
//!     `is_x86_feature_detected!("avx2")` holds);
//!   * [`ThreadedKernel`] — shards M-tiles across `std::thread::scope`
//!     workers over any inner kernel, for large im2col matrices.
//!
//! [`kernel_by_name`] resolves `--kernel scalar|avx2|threaded|auto`;
//! [`default_kernel`] additionally honors the `QOS_NETS_KERNEL`
//! environment variable (how CI forces the scalar kernel).

use std::sync::Arc;

pub const M_TILE: usize = 256;

/// Environment variable consulted by [`default_kernel`]; same values as
/// the `--kernel` CLI flag (`scalar|avx2|threaded|auto`).
pub const KERNEL_ENV: &str = "QOS_NETS_KERNEL";

/// Transpose a row-major (256, 256) LUT to w-major order.
pub fn transpose_lut(lut: &[i32]) -> Vec<i32> {
    debug_assert_eq!(lut.len(), 65536);
    let mut t = vec![0i32; 65536];
    for a in 0..256 {
        for w in 0..256 {
            t[w * 256 + a] = lut[a * 256 + w];
        }
    }
    t
}

// ---------------------------------------------------------------------------
// The dispatch trait
// ---------------------------------------------------------------------------

/// One implementation of the LUT-matmul hot loop.
///
/// Contract (every kernel, pinned bit-exact in `rust/tests/kernels.rs`):
///
/// * **Kernels overwrite `out`; they do not accumulate into it.**  The
///   historical name `matmul_acc` refers to the LUT *accumulation over
///   k* inside the kernel — the output buffer needs no zeroing between
///   calls (the engine reuses one scratch buffer across conv groups for
///   exactly this reason).
/// * Operand codes are u8 values held in i32.  Kernels mask indices to
///   `0..=255` before the LUT gather, so out-of-range codes are a
///   caller bug but never an out-of-bounds read.
/// * `wlut` is the **w-major** transpose ([`transpose_lut`]): row
///   `wlut[w * 256 ..]` holds `lut[a, w]` for all `a` — one KiB per
///   weight code, the unit both the scalar streams and the AVX2
///   gathers operate on.
/// * Integer accumulation is associative, so tiling/sharding choices
///   (M-tile size, thread shard boundaries) can never change results:
///   every kernel is bit-identical to the naive oracle.
///
/// The `*_block` methods compute a contiguous row range of the full
/// (M, N) output: `at`/`m` still describe the *full* (K, M) operand
/// (rows are strided by `m`), `m_lo` is the first output row this call
/// covers, and `out` holds `out.len() / n` rows starting there.  They
/// are the unit [`ThreadedKernel`] shards across workers.
pub trait LutKernel: Send + Sync {
    /// Kernel name for reports and flags ("scalar", "avx2", ...).
    fn name(&self) -> &str;

    /// LUT path for output rows `m_lo .. m_lo + out.len() / n`.
    #[allow(clippy::too_many_arguments)]
    fn lut_block(
        &self,
        at: &[i32],
        wt: &[i32],
        wlut: &[i32],
        m: usize,
        k: usize,
        n: usize,
        m_lo: usize,
        out: &mut [i32],
    );

    /// Exact-multiplier fast path for output rows
    /// `m_lo .. m_lo + out.len() / n`: integer matmul on
    /// zero-point-shifted codes (bit-identical to LUT accumulation +
    /// correction with the exact LUT).
    #[allow(clippy::too_many_arguments)]
    fn exact_block(
        &self,
        at: &[i32],
        wt: &[i32],
        m: usize,
        k: usize,
        n: usize,
        za: i32,
        zw: i32,
        m_lo: usize,
        out: &mut [i32],
    );

    /// Full-matrix LUT accumulation: `at` is A transposed (K, M), `wt`
    /// is W transposed (N, K), `wlut` the w-major LUT; `out` is the
    /// row-major (M, N) result, **overwritten** (see the trait docs).
    #[allow(clippy::too_many_arguments)]
    fn matmul_acc(
        &self,
        at: &[i32],
        wt: &[i32],
        wlut: &[i32],
        m: usize,
        k: usize,
        n: usize,
        out: &mut [i32],
    ) {
        self.lut_block(at, wt, wlut, m, k, n, 0, out);
    }

    /// Full-matrix exact fast path (see [`exact_block`](Self::exact_block)).
    #[allow(clippy::too_many_arguments)]
    fn exact_corrected(
        &self,
        at: &[i32],
        wt: &[i32],
        m: usize,
        k: usize,
        n: usize,
        za: i32,
        zw: i32,
        out: &mut [i32],
    ) {
        self.exact_block(at, wt, m, k, n, za, zw, 0, out);
    }
}

/// Shared operand validation for a block call; returns the row count.
fn check_block(at: &[i32], wt: &[i32], m: usize, k: usize, n: usize, m_lo: usize, out: &[i32]) -> usize {
    assert!(n > 0 && out.len() % n == 0, "out length {} not a multiple of n {n}", out.len());
    let rows = out.len() / n;
    assert!(at.len() >= k * m, "A^T too short: {} < {k}*{m}", at.len());
    assert!(wt.len() >= n * k, "W^T too short: {} < {n}*{k}", wt.len());
    assert!(m_lo + rows <= m, "row range {m_lo}..{} exceeds M {m}", m_lo + rows);
    rows
}

// ---------------------------------------------------------------------------
// Scalar kernel (portable baseline)
// ---------------------------------------------------------------------------

/// The portable 2-way-k-unrolled scalar kernel — the baseline every
/// other kernel is checked against, and the fallback on hosts without
/// AVX2.
#[derive(Debug, Clone, Copy, Default)]
pub struct ScalarKernel;

impl LutKernel for ScalarKernel {
    fn name(&self) -> &str {
        "scalar"
    }

    fn lut_block(
        &self,
        at: &[i32],
        wt: &[i32],
        wlut: &[i32],
        m: usize,
        k: usize,
        n: usize,
        m_lo: usize,
        out: &mut [i32],
    ) {
        scalar_lut_block(at, wt, wlut, m, k, n, m_lo, out);
    }

    fn exact_block(
        &self,
        at: &[i32],
        wt: &[i32],
        m: usize,
        k: usize,
        n: usize,
        za: i32,
        zw: i32,
        m_lo: usize,
        out: &mut [i32],
    ) {
        scalar_exact_block(at, wt, m, k, n, za, zw, m_lo, out);
    }
}

#[allow(clippy::too_many_arguments)]
fn scalar_lut_block(
    at: &[i32],
    wt: &[i32],
    wlut: &[i32],
    m: usize,
    k: usize,
    n: usize,
    m_lo: usize,
    out: &mut [i32],
) {
    let rows = check_block(at, wt, m, k, n, m_lo, out);
    let mut acc_col = [0i32; M_TILE];
    let mut m0 = m_lo;
    let end = m_lo + rows;
    while m0 < end {
        let mt = (end - m0).min(M_TILE);
        for nn in 0..n {
            let col = &mut acc_col[..mt];
            col.fill(0);
            let wrow = &wt[nn * k..(nn + 1) * k];
            // 2-way k-unroll: two independent gather streams per pass to
            // hide L1 load latency (the strided write to `out` happens
            // once per column tile, amortized over K)
            let mut kk = 0;
            while kk + 1 < k {
                let r0 = ((wrow[kk] as usize) & 0xff) << 8;
                let r1 = ((wrow[kk + 1] as usize) & 0xff) << 8;
                let row0 = &wlut[r0..r0 + 256];
                let row1 = &wlut[r1..r1 + 256];
                let a0 = &at[kk * m + m0..kk * m + m0 + mt];
                let a1 = &at[(kk + 1) * m + m0..(kk + 1) * m + m0 + mt];
                for i in 0..mt {
                    // indices are masked to 0..=255, so the unchecked
                    // reads stay inside the 256-entry rows
                    unsafe {
                        *col.get_unchecked_mut(i) += *row0
                            .get_unchecked((*a0.get_unchecked(i) as usize) & 0xff)
                            + *row1.get_unchecked((*a1.get_unchecked(i) as usize) & 0xff);
                    }
                }
                kk += 2;
            }
            if kk < k {
                let r0 = ((wrow[kk] as usize) & 0xff) << 8;
                let row = &wlut[r0..r0 + 256];
                let arow = &at[kk * m + m0..kk * m + m0 + mt];
                for (acc, &a) in col.iter_mut().zip(arow) {
                    *acc += unsafe { *row.get_unchecked((a as usize) & 0xff) };
                }
            }
            for (mm, &v) in col.iter().enumerate() {
                out[(m0 - m_lo + mm) * n + nn] = v;
            }
        }
        m0 += mt;
    }
}

#[allow(clippy::too_many_arguments)]
fn scalar_exact_block(
    at: &[i32],
    wt: &[i32],
    m: usize,
    k: usize,
    n: usize,
    za: i32,
    zw: i32,
    m_lo: usize,
    out: &mut [i32],
) {
    let rows = check_block(at, wt, m, k, n, m_lo, out);
    let mut acc_col = [0i32; M_TILE];
    let mut m0 = m_lo;
    let end = m_lo + rows;
    while m0 < end {
        let mt = (end - m0).min(M_TILE);
        for nn in 0..n {
            let col = &mut acc_col[..mt];
            col.fill(0);
            let wrow = &wt[nn * k..(nn + 1) * k];
            for kk in 0..k {
                let wv = wrow[kk] - zw;
                if wv == 0 {
                    continue;
                }
                let arow = &at[kk * m + m0..kk * m + m0 + mt];
                for (acc, &a) in col.iter_mut().zip(arow) {
                    *acc += (a - za) * wv;
                }
            }
            for (mm, &v) in col.iter().enumerate() {
                out[(m0 - m_lo + mm) * n + nn] = v;
            }
        }
        m0 += mt;
    }
}

// ---------------------------------------------------------------------------
// AVX2 gather kernel (x86_64, runtime-detected)
// ---------------------------------------------------------------------------

/// AVX2 kernel: the w-major KiB LUT rows are gathered eight lanes at a
/// time with `vpgatherdd`, two independent gather streams per k-pair
/// exactly like the scalar unroll.  Only constructible when the CPU
/// reports AVX2 ([`Avx2Kernel::detect`]), so the `unsafe` target-feature
/// calls inside are always valid.
#[cfg(target_arch = "x86_64")]
#[derive(Debug, Clone, Copy)]
pub struct Avx2Kernel {
    _guard: (), // proof of successful detection; see `detect`
}

#[cfg(target_arch = "x86_64")]
impl Avx2Kernel {
    /// The kernel, if this CPU supports AVX2.
    pub fn detect() -> Option<Avx2Kernel> {
        if std::arch::is_x86_feature_detected!("avx2") {
            Some(Avx2Kernel { _guard: () })
        } else {
            None
        }
    }
}

#[cfg(target_arch = "x86_64")]
impl LutKernel for Avx2Kernel {
    fn name(&self) -> &str {
        "avx2"
    }

    fn lut_block(
        &self,
        at: &[i32],
        wt: &[i32],
        wlut: &[i32],
        m: usize,
        k: usize,
        n: usize,
        m_lo: usize,
        out: &mut [i32],
    ) {
        check_block(at, wt, m, k, n, m_lo, out);
        assert!(wlut.len() >= 65536, "w-major LUT too short: {}", wlut.len());
        // SAFETY: construction proves AVX2 is available; bounds are
        // checked above and gather indices are masked to 0..=255.
        unsafe { avx2_lut_block(at, wt, wlut, m, k, n, m_lo, out) }
    }

    fn exact_block(
        &self,
        at: &[i32],
        wt: &[i32],
        m: usize,
        k: usize,
        n: usize,
        za: i32,
        zw: i32,
        m_lo: usize,
        out: &mut [i32],
    ) {
        check_block(at, wt, m, k, n, m_lo, out);
        // SAFETY: construction proves AVX2 is available.
        unsafe { avx2_exact_block(at, wt, m, k, n, za, zw, m_lo, out) }
    }
}

/// # Safety
/// Caller must ensure the CPU supports AVX2, `at`/`wt` cover
/// `(K, M)`/`(N, K)`, `wlut.len() >= 65536`, and `out` holds whole rows
/// of width `n` starting at row `m_lo` with `m_lo + rows <= m`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn avx2_lut_block(
    at: &[i32],
    wt: &[i32],
    wlut: &[i32],
    m: usize,
    k: usize,
    n: usize,
    m_lo: usize,
    out: &mut [i32],
) {
    use std::arch::x86_64::*;
    let rows = out.len() / n;
    let byte_mask = _mm256_set1_epi32(0xff);
    let mut acc_col = [0i32; M_TILE];
    let mut m0 = m_lo;
    let end = m_lo + rows;
    while m0 < end {
        let mt = (end - m0).min(M_TILE);
        for nn in 0..n {
            let col = &mut acc_col[..mt];
            col.fill(0);
            let wrow = &wt[nn * k..(nn + 1) * k];
            let mut kk = 0;
            while kk + 1 < k {
                let r0 = ((wrow[kk] as usize) & 0xff) << 8;
                let r1 = ((wrow[kk + 1] as usize) & 0xff) << 8;
                let row0 = wlut[r0..r0 + 256].as_ptr();
                let row1 = wlut[r1..r1 + 256].as_ptr();
                let a0 = at[kk * m + m0..kk * m + m0 + mt].as_ptr();
                let a1 = at[(kk + 1) * m + m0..(kk + 1) * m + m0 + mt].as_ptr();
                let cp = col.as_mut_ptr();
                let mut i = 0;
                // SAFETY: every load/store covers 8 lanes at offsets
                // < mt (loop bound); gather indices are masked to
                // 0..=255 inside 256-entry rows.
                unsafe {
                    while i + 8 <= mt {
                        let idx0 = _mm256_and_si256(
                            _mm256_loadu_si256(a0.add(i) as *const __m256i),
                            byte_mask,
                        );
                        let idx1 = _mm256_and_si256(
                            _mm256_loadu_si256(a1.add(i) as *const __m256i),
                            byte_mask,
                        );
                        let g0 = _mm256_i32gather_epi32::<4>(row0, idx0);
                        let g1 = _mm256_i32gather_epi32::<4>(row1, idx1);
                        let acc = _mm256_loadu_si256(cp.add(i) as *const __m256i);
                        let sum = _mm256_add_epi32(acc, _mm256_add_epi32(g0, g1));
                        _mm256_storeu_si256(cp.add(i) as *mut __m256i, sum);
                        i += 8;
                    }
                    // tail lanes (mt % 8)
                    while i < mt {
                        *cp.add(i) += *row0.add((*a0.add(i) as usize) & 0xff)
                            + *row1.add((*a1.add(i) as usize) & 0xff);
                        i += 1;
                    }
                }
                kk += 2;
            }
            if kk < k {
                let r0 = ((wrow[kk] as usize) & 0xff) << 8;
                let row = &wlut[r0..r0 + 256];
                let arow = &at[kk * m + m0..kk * m + m0 + mt];
                for (acc, &a) in col.iter_mut().zip(arow) {
                    *acc += row[(a as usize) & 0xff];
                }
            }
            for (mm, &v) in col.iter().enumerate() {
                out[(m0 - m_lo + mm) * n + nn] = v;
            }
        }
        m0 += mt;
    }
}

/// # Safety
/// Caller must ensure the CPU supports AVX2 and the operand bounds of
/// [`avx2_lut_block`] (minus the LUT, which this path does not read).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn avx2_exact_block(
    at: &[i32],
    wt: &[i32],
    m: usize,
    k: usize,
    n: usize,
    za: i32,
    zw: i32,
    m_lo: usize,
    out: &mut [i32],
) {
    use std::arch::x86_64::*;
    let rows = out.len() / n;
    let za_v = _mm256_set1_epi32(za);
    let mut acc_col = [0i32; M_TILE];
    let mut m0 = m_lo;
    let end = m_lo + rows;
    while m0 < end {
        let mt = (end - m0).min(M_TILE);
        for nn in 0..n {
            let col = &mut acc_col[..mt];
            col.fill(0);
            let wrow = &wt[nn * k..(nn + 1) * k];
            for kk in 0..k {
                let wv = wrow[kk] - zw;
                if wv == 0 {
                    continue;
                }
                let arow = at[kk * m + m0..kk * m + m0 + mt].as_ptr();
                let cp = col.as_mut_ptr();
                // SAFETY: 8-lane accesses bounded by mt; wrapping i32
                // lane arithmetic matches the scalar release semantics.
                unsafe {
                    let wv_v = _mm256_set1_epi32(wv);
                    let mut i = 0;
                    while i + 8 <= mt {
                        let a = _mm256_loadu_si256(arow.add(i) as *const __m256i);
                        let prod = _mm256_mullo_epi32(_mm256_sub_epi32(a, za_v), wv_v);
                        let acc = _mm256_loadu_si256(cp.add(i) as *const __m256i);
                        _mm256_storeu_si256(cp.add(i) as *mut __m256i, _mm256_add_epi32(acc, prod));
                        i += 8;
                    }
                    while i < mt {
                        *cp.add(i) += ((*arow.add(i)).wrapping_sub(za)).wrapping_mul(wv);
                        i += 1;
                    }
                }
            }
            for (mm, &v) in col.iter().enumerate() {
                out[(m0 - m_lo + mm) * n + nn] = v;
            }
        }
        m0 += mt;
    }
}

// ---------------------------------------------------------------------------
// Threaded wrapper (M-tile sharding)
// ---------------------------------------------------------------------------

/// Shards the output's M dimension across `std::thread::scope` workers,
/// delegating each contiguous tile-aligned row range to an inner
/// kernel.  Integer accumulation makes shard boundaries invisible in
/// the result, so this is bit-identical to the inner kernel by
/// construction.  Small blocks (under two M-tiles per worker-pair) run
/// inline — the scope overhead only pays off on large im2col matrices
/// (big serving batches, fleet worker chunks).
pub struct ThreadedKernel {
    inner: Arc<dyn LutKernel>,
    threads: usize,
    name: String,
}

impl ThreadedKernel {
    /// Wrap `inner`, sharding across up to `threads` workers (values
    /// below 2 make this a pass-through).
    pub fn new(inner: Arc<dyn LutKernel>, threads: usize) -> ThreadedKernel {
        let name = format!("threaded({}x{})", inner.name(), threads.max(1));
        ThreadedKernel {
            inner,
            threads: threads.max(1),
            name,
        }
    }

    /// Wrap `inner` with one worker per available hardware thread.
    pub fn with_available_parallelism(inner: Arc<dyn LutKernel>) -> ThreadedKernel {
        let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        ThreadedKernel::new(inner, threads)
    }

    /// Split `out` (rows starting at `m_lo`) into tile-aligned shards
    /// and run `f` on each concurrently.
    fn shard(&self, n: usize, m_lo: usize, out: &mut [i32], f: impl Fn(usize, &mut [i32]) + Sync) {
        let rows = out.len() / n;
        let tiles = rows.div_ceil(M_TILE);
        let shards = self.threads.min(tiles);
        let chunk_rows = tiles.div_ceil(shards) * M_TILE;
        std::thread::scope(|s| {
            let mut rest = out;
            let mut lo = m_lo;
            while !rest.is_empty() {
                let take = (chunk_rows * n).min(rest.len());
                let (head, tail) = std::mem::take(&mut rest).split_at_mut(take);
                let lo_here = lo;
                lo += take / n;
                rest = tail;
                let f = &f;
                s.spawn(move || f(lo_here, head));
            }
        });
    }
}

impl LutKernel for ThreadedKernel {
    fn name(&self) -> &str {
        &self.name
    }

    fn lut_block(
        &self,
        at: &[i32],
        wt: &[i32],
        wlut: &[i32],
        m: usize,
        k: usize,
        n: usize,
        m_lo: usize,
        out: &mut [i32],
    ) {
        let rows = check_block(at, wt, m, k, n, m_lo, out);
        if self.threads < 2 || rows < 2 * M_TILE {
            return self.inner.lut_block(at, wt, wlut, m, k, n, m_lo, out);
        }
        self.shard(n, m_lo, out, |lo, block| {
            self.inner.lut_block(at, wt, wlut, m, k, n, lo, block)
        });
    }

    fn exact_block(
        &self,
        at: &[i32],
        wt: &[i32],
        m: usize,
        k: usize,
        n: usize,
        za: i32,
        zw: i32,
        m_lo: usize,
        out: &mut [i32],
    ) {
        let rows = check_block(at, wt, m, k, n, m_lo, out);
        if self.threads < 2 || rows < 2 * M_TILE {
            return self.inner.exact_block(at, wt, m, k, n, za, zw, m_lo, out);
        }
        self.shard(n, m_lo, out, |lo, block| {
            self.inner.exact_block(at, wt, m, k, n, za, zw, lo, block)
        });
    }
}

// ---------------------------------------------------------------------------
// Selection
// ---------------------------------------------------------------------------

/// The best single-threaded kernel this host supports: AVX2 when the
/// CPU reports it, the portable scalar kernel otherwise.  This is what
/// `--kernel auto` resolves to — threading is opt-in (`--kernel
/// threaded`) because the serving stack already parallelizes across
/// worker backends and nesting both oversubscribes the host.
pub fn detect_kernel() -> Arc<dyn LutKernel> {
    #[cfg(target_arch = "x86_64")]
    if let Some(k) = Avx2Kernel::detect() {
        return Arc::new(k);
    }
    Arc::new(ScalarKernel)
}

/// Resolve a `--kernel` flag value.  `auto` = [`detect_kernel`];
/// `threaded` wraps the detected kernel with one worker per hardware
/// thread; an explicit `avx2` on a host without AVX2 is an error (use
/// `auto` for graceful fallback).
pub fn kernel_by_name(name: &str) -> anyhow::Result<Arc<dyn LutKernel>> {
    match name {
        "auto" => Ok(detect_kernel()),
        "scalar" => Ok(Arc::new(ScalarKernel)),
        "threaded" => Ok(Arc::new(ThreadedKernel::with_available_parallelism(detect_kernel()))),
        "avx2" => {
            #[cfg(target_arch = "x86_64")]
            if let Some(k) = Avx2Kernel::detect() {
                return Ok(Arc::new(k));
            }
            anyhow::bail!("this host has no AVX2 (use --kernel auto for detection with fallback)")
        }
        other => anyhow::bail!("unknown kernel {other:?} (scalar|avx2|threaded|auto)"),
    }
}

/// The kernel new engines use when nothing is specified: the
/// `QOS_NETS_KERNEL` environment variable when set (invalid values warn
/// and fall back), else [`detect_kernel`].
pub fn default_kernel() -> Arc<dyn LutKernel> {
    if let Ok(name) = std::env::var(KERNEL_ENV) {
        if !name.is_empty() {
            match kernel_by_name(&name) {
                Ok(k) => return k,
                Err(e) => {
                    crate::obs::log!(Warn, "{KERNEL_ENV}={name}: {e}; using auto-detection")
                }
            }
        }
    }
    detect_kernel()
}

/// Every kernel this host can run, for benches and cross-kernel tests:
/// scalar always, AVX2 when detected, and the threaded wrapper over the
/// detected kernel.
pub fn available_kernels() -> Vec<Arc<dyn LutKernel>> {
    let mut out: Vec<Arc<dyn LutKernel>> = vec![Arc::new(ScalarKernel)];
    #[cfg(target_arch = "x86_64")]
    if let Some(k) = Avx2Kernel::detect() {
        out.push(Arc::new(k));
    }
    out.push(Arc::new(ThreadedKernel::with_available_parallelism(detect_kernel())));
    out
}

// ---------------------------------------------------------------------------
// Free-function scalar entry points (selftest / benches / tests)
// ---------------------------------------------------------------------------

/// Scalar LUT accumulation over the full matrix: `at` is A transposed
/// (K, M), `wt` is W transposed (N, K), `wlut` the w-major LUT; `out`
/// is row-major (M, N) and **overwritten** (see [`LutKernel`] for the
/// full contract — the "acc" names the accumulation over k).
pub fn lut_matmul_acc(at: &[i32], wt: &[i32], wlut: &[i32], m: usize, k: usize, n: usize, out: &mut [i32]) {
    scalar_lut_block(at, wt, wlut, m, k, n, 0, out);
}

/// Scalar exact-multiplier fast path: integer matmul on
/// zero-point-shifted codes (bit-identical to lut accumulation +
/// correction with the exact LUT).  `out` is overwritten.
#[allow(clippy::too_many_arguments)]
pub fn exact_matmul_corrected(
    at: &[i32],
    wt: &[i32],
    m: usize,
    k: usize,
    n: usize,
    za: i32,
    zw: i32,
    out: &mut [i32],
) {
    scalar_exact_block(at, wt, m, k, n, za, zw, 0, out);
}

/// Zero-point correction in place:
/// `corr = acc - za * SW[n] - zw * SA[m] + K * za * zw`.
pub fn apply_corrections(
    acc: &mut [i32],
    sa: &[i32],
    sw: &[i32],
    m: usize,
    k: usize,
    n: usize,
    za: i32,
    zw: i32,
) {
    let kzz = (k as i32) * za * zw;
    for mm in 0..m {
        let base = -zw * sa[mm] + kzz;
        let row = &mut acc[mm * n..(mm + 1) * n];
        for nn in 0..n {
            row[nn] += base - za * sw[nn];
        }
    }
}

/// Column sums of A^T (per-m code sums) and row sums of W^T (per-n).
pub fn code_sums(at: &[i32], wt: &[i32], m: usize, k: usize, n: usize) -> (Vec<i32>, Vec<i32>) {
    let sa = row_code_sums(at, m, k);
    let mut sw = vec![0i32; n];
    for (nn, chunk) in wt.chunks_exact(k).enumerate() {
        sw[nn] = chunk.iter().sum();
    }
    (sa, sw)
}

/// Per-m code sums of A^T alone (the W^T sums are cached by the engine).
pub fn row_code_sums(at: &[i32], m: usize, k: usize) -> Vec<i32> {
    let mut sa = vec![0i32; m];
    for kk in 0..k {
        let arow = &at[kk * m..(kk + 1) * m];
        for (mm, &a) in arow.iter().enumerate() {
            sa[mm] += a;
        }
    }
    sa
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::muldb::MulDb;
    use crate::util::rng::Rng;

    fn naive(a: &[i32], w: &[i32], lut: &[i32], m: usize, k: usize, n: usize) -> Vec<i32> {
        let mut out = vec![0i32; m * n];
        for mm in 0..m {
            for nn in 0..n {
                let mut acc = 0;
                for kk in 0..k {
                    acc += lut[(a[mm * k + kk] as usize) * 256 + w[kk * n + nn] as usize];
                }
                out[mm * n + nn] = acc;
            }
        }
        out
    }

    fn transpose(x: &[i32], rows: usize, cols: usize) -> Vec<i32> {
        let mut t = vec![0i32; x.len()];
        for r in 0..rows {
            for c in 0..cols {
                t[c * rows + r] = x[r * cols + c];
            }
        }
        t
    }

    #[test]
    fn blocked_matches_naive() {
        let db = MulDb::generate();
        let mut rng = Rng::new(5);
        for &(m, k, n, mid) in &[(3usize, 7usize, 5usize, 9usize), (300, 33, 17, 19), (64, 64, 64, 23)] {
            let a: Vec<i32> = (0..m * k).map(|_| rng.below(256) as i32).collect();
            let w: Vec<i32> = (0..k * n).map(|_| rng.below(256) as i32).collect();
            let at = transpose(&a, m, k);
            let wt = transpose(&w, k, n);
            let wlut = transpose_lut(db.lut(mid));
            let mut out = vec![0i32; m * n];
            lut_matmul_acc(&at, &wt, &wlut, m, k, n, &mut out);
            assert_eq!(out, naive(&a, &w, db.lut(mid), m, k, n), "m{m} k{k} n{n} mid{mid}");
        }
    }

    #[test]
    fn exact_fast_path_equals_lut_plus_corrections() {
        let db = MulDb::generate();
        let mut rng = Rng::new(6);
        let (m, k, n) = (17usize, 29usize, 13usize);
        let (za, zw) = (128i32, 117i32);
        let a: Vec<i32> = (0..m * k).map(|_| rng.below(256) as i32).collect();
        let w: Vec<i32> = (0..k * n).map(|_| rng.below(256) as i32).collect();
        let at = transpose(&a, m, k);
        let wt = transpose(&w, k, n);
        let wlut = transpose_lut(db.lut(0));
        let mut lut_out = vec![0i32; m * n];
        lut_matmul_acc(&at, &wt, &wlut, m, k, n, &mut lut_out);
        let (sa, sw) = code_sums(&at, &wt, m, k, n);
        apply_corrections(&mut lut_out, &sa, &sw, m, k, n, za, zw);
        let mut fast = vec![0i32; m * n];
        exact_matmul_corrected(&at, &wt, m, k, n, za, zw, &mut fast);
        assert_eq!(lut_out, fast);
    }

    #[test]
    fn kernel_registry_resolves_flag_values() {
        assert_eq!(kernel_by_name("scalar").unwrap().name(), "scalar");
        assert!(kernel_by_name("auto").is_ok());
        assert!(kernel_by_name("threaded").unwrap().name().starts_with("threaded("));
        assert!(kernel_by_name("simd128").is_err());
        // every host runs at least scalar + the threaded wrapper
        assert!(available_kernels().len() >= 2);
    }

    #[test]
    fn threaded_kernel_matches_inner_on_tail_shapes() {
        // rows not a multiple of M_TILE and more threads than tiles
        let db = MulDb::generate();
        let mut rng = Rng::new(7);
        let (m, k, n) = (3 * M_TILE + 37, 9usize, 5usize);
        let a: Vec<i32> = (0..m * k).map(|_| rng.below(256) as i32).collect();
        let w: Vec<i32> = (0..k * n).map(|_| rng.below(256) as i32).collect();
        let at = transpose(&a, m, k);
        let wt = transpose(&w, k, n);
        let wlut = transpose_lut(db.lut(11));
        let mut want = vec![0i32; m * n];
        ScalarKernel.matmul_acc(&at, &wt, &wlut, m, k, n, &mut want);
        for threads in [2usize, 3, 64] {
            let tk = ThreadedKernel::new(Arc::new(ScalarKernel), threads);
            let mut got = vec![0i32; m * n];
            tk.matmul_acc(&at, &wt, &wlut, m, k, n, &mut got);
            assert_eq!(got, want, "threads={threads}");
            let mut ex_want = vec![0i32; m * n];
            ScalarKernel.exact_corrected(&at, &wt, m, k, n, 128, 120, &mut ex_want);
            let mut ex_got = vec![0i32; m * n];
            tk.exact_corrected(&at, &wt, m, k, n, 128, 120, &mut ex_got);
            assert_eq!(ex_got, ex_want, "exact threads={threads}");
        }
    }

    #[test]
    fn kernels_overwrite_out_rather_than_accumulate() {
        // the LutKernel contract: a poisoned output buffer must not
        // leak into results (the engine reuses one scratch across
        // conv groups relying on this)
        let db = MulDb::generate();
        let mut rng = Rng::new(8);
        let (m, k, n) = (19usize, 6usize, 4usize);
        let a: Vec<i32> = (0..m * k).map(|_| rng.below(256) as i32).collect();
        let w: Vec<i32> = (0..k * n).map(|_| rng.below(256) as i32).collect();
        let at = transpose(&a, m, k);
        let wt = transpose(&w, k, n);
        let wlut = transpose_lut(db.lut(3));
        for kernel in available_kernels() {
            let mut clean = vec![0i32; m * n];
            kernel.matmul_acc(&at, &wt, &wlut, m, k, n, &mut clean);
            let mut poisoned = vec![i32::MAX; m * n];
            kernel.matmul_acc(&at, &wt, &wlut, m, k, n, &mut poisoned);
            assert_eq!(poisoned, clean, "{} accumulated into out", kernel.name());
        }
    }
}
