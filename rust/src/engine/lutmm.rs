//! Blocked LUT matmul — the L3 hot loop (native mirror of the L1 kernel).
//!
//! Computes  acc[m, n] = sum_k lut[a[m, k], w[k, n]]  over u8 codes held
//! in i32, exactly like the Pallas kernel / ref.py oracle.
//!
//! Layout strategy (see EXPERIMENTS.md §Perf for the measured iteration):
//!   * the LUT is transposed once per multiplier to w-major order
//!     (`wlut[w * 256 + a]`), so for a fixed weight code the 256-entry
//!     row is one KiB of hot cache;
//!   * A is transposed to (K, M) so the inner m-loop reads contiguous
//!     indices; W is transposed to (N, K) so each output column walks a
//!     contiguous code row;
//!   * M is tiled so the A^T tile stays cache-resident while all N
//!     columns sweep over it.

pub const M_TILE: usize = 256;

/// Transpose a row-major (256, 256) LUT to w-major order.
pub fn transpose_lut(lut: &[i32]) -> Vec<i32> {
    debug_assert_eq!(lut.len(), 65536);
    let mut t = vec![0i32; 65536];
    for a in 0..256 {
        for w in 0..256 {
            t[w * 256 + a] = lut[a * 256 + w];
        }
    }
    t
}

/// Raw accumulation: `at` is A transposed (K, M), `wt` is W transposed
/// (N, K), `wlut` is the w-major LUT. Output row-major (M, N).
pub fn lut_matmul_acc(at: &[i32], wt: &[i32], wlut: &[i32], m: usize, k: usize, n: usize, out: &mut [i32]) {
    debug_assert_eq!(at.len(), k * m);
    debug_assert_eq!(wt.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    let mut acc_col = [0i32; M_TILE];
    let mut m0 = 0;
    while m0 < m {
        let mt = (m - m0).min(M_TILE);
        for nn in 0..n {
            let col = &mut acc_col[..mt];
            col.fill(0);
            let wrow = &wt[nn * k..(nn + 1) * k];
            // 2-way k-unroll: two independent gather streams per pass to
            // hide L1 load latency (the strided write to `out` happens
            // once per column tile, amortized over K)
            let mut kk = 0;
            while kk + 1 < k {
                let r0 = (wrow[kk] as usize) << 8;
                let r1 = (wrow[kk + 1] as usize) << 8;
                let row0 = &wlut[r0..r0 + 256];
                let row1 = &wlut[r1..r1 + 256];
                let a0 = &at[kk * m + m0..kk * m + m0 + mt];
                let a1 = &at[(kk + 1) * m + m0..(kk + 1) * m + m0 + mt];
                for i in 0..mt {
                    unsafe {
                        *col.get_unchecked_mut(i) += *row0.get_unchecked(*a0.get_unchecked(i) as usize)
                            + *row1.get_unchecked(*a1.get_unchecked(i) as usize);
                    }
                }
                kk += 2;
            }
            if kk < k {
                let r0 = (wrow[kk] as usize) << 8;
                let row = &wlut[r0..r0 + 256];
                let arow = &at[kk * m + m0..kk * m + m0 + mt];
                for (acc, &a) in col.iter_mut().zip(arow) {
                    *acc += unsafe { *row.get_unchecked(a as usize) };
                }
            }
            for (mm, &v) in col.iter().enumerate() {
                out[(m0 + mm) * n + nn] = v;
            }
        }
        m0 += mt;
    }
}

/// Exact-multiplier fast path: integer matmul on zero-point-shifted codes
/// (bit-identical to lut accumulation + correction with the exact LUT).
pub fn exact_matmul_corrected(
    at: &[i32],
    wt: &[i32],
    m: usize,
    k: usize,
    n: usize,
    za: i32,
    zw: i32,
    out: &mut [i32],
) {
    let mut acc_col = [0i32; M_TILE];
    let mut m0 = 0;
    while m0 < m {
        let mt = (m - m0).min(M_TILE);
        for nn in 0..n {
            let col = &mut acc_col[..mt];
            col.fill(0);
            let wrow = &wt[nn * k..(nn + 1) * k];
            for kk in 0..k {
                let wv = wrow[kk] - zw;
                if wv == 0 {
                    continue;
                }
                let arow = &at[kk * m + m0..kk * m + m0 + mt];
                for (acc, &a) in col.iter_mut().zip(arow) {
                    *acc += (a - za) * wv;
                }
            }
            for (mm, &v) in col.iter().enumerate() {
                out[(m0 + mm) * n + nn] = v;
            }
        }
        m0 += mt;
    }
}

/// Zero-point correction in place:
/// `corr = acc - za * SW[n] - zw * SA[m] + K * za * zw`.
pub fn apply_corrections(
    acc: &mut [i32],
    sa: &[i32],
    sw: &[i32],
    m: usize,
    k: usize,
    n: usize,
    za: i32,
    zw: i32,
) {
    let kzz = (k as i32) * za * zw;
    for mm in 0..m {
        let base = -zw * sa[mm] + kzz;
        let row = &mut acc[mm * n..(mm + 1) * n];
        for nn in 0..n {
            row[nn] += base - za * sw[nn];
        }
    }
}

/// Column sums of A^T (per-m code sums) and row sums of W^T (per-n).
pub fn code_sums(at: &[i32], wt: &[i32], m: usize, k: usize, n: usize) -> (Vec<i32>, Vec<i32>) {
    let sa = row_code_sums(at, m, k);
    let mut sw = vec![0i32; n];
    for (nn, chunk) in wt.chunks_exact(k).enumerate() {
        sw[nn] = chunk.iter().sum();
    }
    (sa, sw)
}

/// Per-m code sums of A^T alone (the W^T sums are cached by the engine).
pub fn row_code_sums(at: &[i32], m: usize, k: usize) -> Vec<i32> {
    let mut sa = vec![0i32; m];
    for kk in 0..k {
        let arow = &at[kk * m..(kk + 1) * m];
        for (mm, &a) in arow.iter().enumerate() {
            sa[mm] += a;
        }
    }
    sa
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::muldb::MulDb;
    use crate::util::rng::Rng;

    fn naive(a: &[i32], w: &[i32], lut: &[i32], m: usize, k: usize, n: usize) -> Vec<i32> {
        let mut out = vec![0i32; m * n];
        for mm in 0..m {
            for nn in 0..n {
                let mut acc = 0;
                for kk in 0..k {
                    acc += lut[(a[mm * k + kk] as usize) * 256 + w[kk * n + nn] as usize];
                }
                out[mm * n + nn] = acc;
            }
        }
        out
    }

    fn transpose(x: &[i32], rows: usize, cols: usize) -> Vec<i32> {
        let mut t = vec![0i32; x.len()];
        for r in 0..rows {
            for c in 0..cols {
                t[c * rows + r] = x[r * cols + c];
            }
        }
        t
    }

    #[test]
    fn blocked_matches_naive() {
        let db = MulDb::generate();
        let mut rng = Rng::new(5);
        for &(m, k, n, mid) in &[(3usize, 7usize, 5usize, 9usize), (300, 33, 17, 19), (64, 64, 64, 23)] {
            let a: Vec<i32> = (0..m * k).map(|_| rng.below(256) as i32).collect();
            let w: Vec<i32> = (0..k * n).map(|_| rng.below(256) as i32).collect();
            let at = transpose(&a, m, k);
            let wt = transpose(&w, k, n);
            let wlut = transpose_lut(db.lut(mid));
            let mut out = vec![0i32; m * n];
            lut_matmul_acc(&at, &wt, &wlut, m, k, n, &mut out);
            assert_eq!(out, naive(&a, &w, db.lut(mid), m, k, n), "m{m} k{k} n{n} mid{mid}");
        }
    }

    #[test]
    fn exact_fast_path_equals_lut_plus_corrections() {
        let db = MulDb::generate();
        let mut rng = Rng::new(6);
        let (m, k, n) = (17usize, 29usize, 13usize);
        let (za, zw) = (128i32, 117i32);
        let a: Vec<i32> = (0..m * k).map(|_| rng.below(256) as i32).collect();
        let w: Vec<i32> = (0..k * n).map(|_| rng.below(256) as i32).collect();
        let at = transpose(&a, m, k);
        let wt = transpose(&w, k, n);
        let wlut = transpose_lut(db.lut(0));
        let mut lut_out = vec![0i32; m * n];
        lut_matmul_acc(&at, &wt, &wlut, m, k, n, &mut lut_out);
        let (sa, sw) = code_sums(&at, &wt, m, k, n);
        apply_corrections(&mut lut_out, &sa, &sw, m, k, n, za, zw);
        let mut fast = vec![0i32; m * n];
        exact_matmul_corrected(&at, &wt, m, k, n, za, zw, &mut fast);
        assert_eq!(lut_out, fast);
    }
}
