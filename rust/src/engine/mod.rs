//! Native quantized inference engine: executes the exported graph with
//! bit-exact LUT arithmetic (the deployment semantics of the paper's
//! approximate hardware).
//!
//! Data flow per approximable layer (conv / dense):
//!   f32 input -> u8 codes (round-half-even, clamp) -> im2col ->
//!   LUT accumulation -> zero-point corrections -> fused
//!   dequant*BN scale + bias -> activation -> f32 output.
//! `add` / `gap` nodes run in f32 between layers, matching the L2
//! executor's semantics (quantization happens at layer *inputs*).
//!
//! Operating-point switching is a pointer swap: `OperatingPoint` bundles
//! the per-layer multiplier assignment + the BN overlay parameters; the
//! engine holds all LUTs (transposed, cached) so switching costs nothing
//! on the data path.  [`Engine::prepare_op`] precompiles the per-OP
//! weight/LUT caches up front (the serving path via
//! `backend::NativeBackend` calls it for every ladder rung) so `forward`
//! never builds them lazily on the hot path.
//!
//! The arithmetic itself is dispatched through a runtime-selected
//! [`lutmm::LutKernel`] (scalar / AVX2 / threaded — see the `lutmm`
//! module docs); [`Engine::new`] picks [`lutmm::default_kernel`] and
//! [`Engine::with_kernel`] / [`Engine::set_kernel`] override it (the
//! CLI's `--kernel` flag).

pub mod lutmm;

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::engine::lutmm::LutKernel;
use crate::muldb::MulDb;
use crate::nn::{Graph, LayerParams, ModelParams, Node, NodeKind};

/// One runtime configuration: multiplier per layer + parameter set.
#[derive(Debug, Clone)]
pub struct OperatingPoint {
    pub name: String,
    /// layer name -> multiplier id
    pub assignment: HashMap<String, usize>,
    pub params: ModelParams,
    /// MAC-weighted relative multiplication power (from the search).
    pub relative_power: f64,
}

pub struct Engine {
    graph: Arc<Graph>,
    db: Arc<MulDb>,
    /// transposed (w-major) LUT cache, built lazily per multiplier id
    wluts: Vec<Option<Vec<i32>>>,
    /// per-(op, layer, group) transposed weight codes + column sums,
    /// rebuilt only when the operating point changes (serving hot path);
    /// each entry carries the fingerprint of the weight codes it was
    /// built from, so re-preparing a same-named OP with different
    /// weights (reloaded plan, full-retrain overlay) replaces the stale
    /// entry instead of silently serving it
    wt_cache: HashMap<(String, String, usize), WtEntry>,
    /// the matmul hot-loop implementation (see [`lutmm`])
    kernel: Arc<dyn LutKernel>,
}

/// One cached weight transpose: W^T codes + per-column code sums, tagged
/// with the fingerprint of the `w_codes` they were derived from.
struct WtEntry {
    fingerprint: u64,
    wt: Vec<i32>,
    sw: Vec<i32>,
}

/// FNV-1a over a layer's weight codes — the staleness tag for
/// [`Engine`]'s weight-transpose cache.  Only `w_codes` feed the cache
/// (post-scale/bias are read fresh from the operating point every
/// forward), so only they are hashed.
///
/// Recomputed on every `ensure_layer_caches` call by design: a pointer
/// identity short-circuit would serve stale codes when a reloaded
/// plan's `Vec` lands on a freed predecessor's address — exactly the
/// staleness class this tag exists to kill.  The cost is one
/// multiply/XOR per weight, a vanishing fraction of the layer's
/// `m*k*n` matmul work.
fn params_fingerprint(lp: &LayerParams) -> u64 {
    crate::util::hash::fnv1a_words(lp.w_codes.iter().map(|&c| c as u32 as u64))
}

#[derive(Debug, Clone)]
struct Act {
    shape: Vec<usize>, // [B, H, W, C] or [B, C]
    data: Vec<f32>,
}

impl Engine {
    /// An engine with the host's default kernel ([`lutmm::default_kernel`]:
    /// the `QOS_NETS_KERNEL` env var when set, else feature detection).
    pub fn new(graph: Arc<Graph>, db: Arc<MulDb>) -> Self {
        Self::with_kernel(graph, db, lutmm::default_kernel())
    }

    /// An engine running a specific [`LutKernel`] (the `--kernel` flag).
    pub fn with_kernel(graph: Arc<Graph>, db: Arc<MulDb>, kernel: Arc<dyn LutKernel>) -> Self {
        let n = db.len();
        Engine {
            graph,
            db,
            wluts: vec![None; n],
            wt_cache: HashMap::new(),
            kernel,
        }
    }

    /// Swap the matmul kernel (safe at any time — kernels share no
    /// state and are bit-identical, so caches stay valid).
    pub fn set_kernel(&mut self, kernel: Arc<dyn LutKernel>) {
        self.kernel = kernel;
    }

    /// The active matmul kernel.
    pub fn kernel(&self) -> &dyn LutKernel {
        self.kernel.as_ref()
    }

    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// Ensure the transposed LUT for a multiplier id is resident.
    fn ensure_wlut(&mut self, mid: usize) {
        if mid != 0 && self.wluts[mid].is_none() {
            self.wluts[mid] = Some(lutmm::transpose_lut(self.db.lut(mid)));
        }
    }

    /// Transposed weight codes + per-output-column code sums for one
    /// (layer, group); weights are stored (K, cout) row-major and the
    /// group's columns are [g*cg_out, (g+1)*cg_out).
    fn build_wt(lp: &LayerParams, k: usize, cout: usize, g: usize, cg_out: usize) -> (Vec<i32>, Vec<i32>) {
        let mut wt = vec![0i32; cg_out * k];
        for kk in 0..k {
            for nn in 0..cg_out {
                wt[nn * k + kk] = lp.w_codes[kk * cout + g * cg_out + nn];
            }
        }
        let sw: Vec<i32> = wt.chunks_exact(k).map(|c| c.iter().sum()).collect();
        (wt, sw)
    }

    /// Populate the weight/LUT caches for one layer under an operating
    /// point; `forward` also calls this lazily so direct Engine users
    /// keep working, but [`Engine::prepare_op`] front-loads the cost.
    fn ensure_layer_caches(&mut self, op: &OperatingPoint, node: &Node) -> Result<()> {
        let lp = op
            .params
            .layers
            .get(&node.name)
            .with_context(|| format!("{}: missing params", node.name))?;
        let mid = *op.assignment.get(&node.name).unwrap_or(&0);
        self.ensure_wlut(mid);
        let (groups, k, cg_out) = match node.kind {
            NodeKind::Dense => (1usize, node.cin, node.cout),
            _ => (
                node.groups,
                node.ksize * node.ksize * (node.cin / node.groups),
                node.cout / node.groups,
            ),
        };
        let fingerprint = params_fingerprint(lp);
        for g in 0..groups {
            let key = (op.name.clone(), node.name.clone(), g);
            let fresh = self
                .wt_cache
                .get(&key)
                .is_some_and(|e| e.fingerprint == fingerprint);
            if !fresh {
                let (wt, sw) = Self::build_wt(lp, k, node.cout, g, cg_out);
                // insert replaces (= evicts) any stale entry for this key
                self.wt_cache.insert(key, WtEntry { fingerprint, wt, sw });
            }
        }
        Ok(())
    }

    /// Precompile every per-layer weight transpose and LUT for an
    /// operating point so the serving hot path never builds them lazily.
    pub fn prepare_op(&mut self, op: &OperatingPoint) -> Result<()> {
        let graph = Arc::clone(&self.graph);
        for node in &graph.nodes {
            if matches!(node.kind, NodeKind::Conv | NodeKind::Dense) {
                self.ensure_layer_caches(op, node)?;
            }
        }
        Ok(())
    }

    /// Forward a batch: images [B, H, W, C] f32 -> logits [B, classes].
    pub fn forward(&mut self, op: &OperatingPoint, images: &[f32], batch: usize) -> Result<Vec<f32>> {
        let ishape = &self.graph.input_shape;
        let expect = batch * ishape.iter().product::<usize>();
        if images.len() != expect {
            bail!("input size {} != expected {}", images.len(), expect);
        }
        let span_t0 = crate::obs::recording().then(std::time::Instant::now);
        let mut vals: HashMap<usize, Act> = HashMap::new();
        vals.insert(
            0,
            Act {
                shape: vec![batch, ishape[0], ishape[1], ishape[2]],
                data: images.to_vec(),
            },
        );

        let mut logits = None;
        // hold the graph by Arc so conv/dense can borrow &mut self
        // (caches) without cloning every node each batch
        let graph = Arc::clone(&self.graph);
        // last consumer position per node id: activations are dropped
        // right after their final consumer runs, so residual-heavy
        // graphs hold only the live frontier instead of every
        // intermediate for the whole pass
        let mut last_use: HashMap<usize, usize> = HashMap::new();
        for (pos, node) in graph.nodes.iter().enumerate() {
            for &inp in &node.inputs {
                last_use.insert(inp, pos);
            }
        }
        for (pos, node) in graph.nodes.iter().enumerate() {
            match node.kind {
                NodeKind::Input => {}
                NodeKind::Conv => {
                    let x = vals.get(&node.inputs[0]).context("conv input")?;
                    let y = self.conv(node, op, x)?;
                    vals.insert(node.id, y);
                }
                NodeKind::Dense => {
                    let x = vals.get(&node.inputs[0]).context("dense input")?;
                    let y = self.dense(node, op, x)?;
                    vals.insert(node.id, y);
                }
                NodeKind::Add => {
                    let a = vals.get(&node.inputs[0]).context("add lhs")?;
                    let b = vals.get(&node.inputs[1]).context("add rhs")?;
                    let data: Vec<f32> = a
                        .data
                        .iter()
                        .zip(&b.data)
                        .map(|(x, y)| node.act.apply(x + y))
                        .collect();
                    vals.insert(
                        node.id,
                        Act {
                            shape: a.shape.clone(),
                            data,
                        },
                    );
                }
                NodeKind::Gap => {
                    let x = vals.get(&node.inputs[0]).context("gap input")?;
                    let (b, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
                    let mut out = vec![0f32; b * c];
                    for bi in 0..b {
                        for pos in 0..h * w {
                            let base = (bi * h * w + pos) * c;
                            for ci in 0..c {
                                out[bi * c + ci] += x.data[base + ci];
                            }
                        }
                        for ci in 0..c {
                            out[bi * c + ci] /= (h * w) as f32;
                        }
                    }
                    vals.insert(
                        node.id,
                        Act {
                            shape: vec![b, c],
                            data: out,
                        },
                    );
                }
                NodeKind::Output => {
                    // take (not clone) when this is the input's last use
                    logits = if last_use.get(&node.inputs[0]) == Some(&pos) {
                        vals.remove(&node.inputs[0])
                    } else {
                        vals.get(&node.inputs[0]).cloned()
                    };
                }
            }
            // free every activation whose final consumer just ran
            for &inp in &node.inputs {
                if last_use.get(&inp) == Some(&pos) {
                    vals.remove(&inp);
                }
            }
        }
        if let Some(t0) = span_t0 {
            crate::obs::publish(crate::obs::ObsEvent::EngineForward {
                op: op.name.clone(),
                images: batch,
                dur_us: t0.elapsed().as_micros() as u64,
            });
        }
        Ok(logits.context("no output produced")?.data)
    }

    fn quantize(x: &[f32], scale: f32, zp: i32) -> Vec<i32> {
        x.iter()
            .map(|&v| ((v / scale).round_ties_even() as i32 + zp).clamp(0, 255))
            .collect()
    }

    /// im2col producing the *transposed* (K, M) code matrix the hot loop
    /// wants, with padding taps filled by the zero-point code.
    #[allow(clippy::too_many_arguments)]
    fn im2col_t(
        codes: &[i32],
        b: usize,
        h: usize,
        w: usize,
        cin: usize,
        ksize: usize,
        stride: usize,
        pad: usize,
        za: i32,
        group: usize,
        groups: usize,
    ) -> (Vec<i32>, usize, usize, usize) {
        let oh = (h + 2 * pad - ksize) / stride + 1;
        let ow = (w + 2 * pad - ksize) / stride + 1;
        let cg = cin / groups;
        let k = ksize * ksize * cg;
        let m = b * oh * ow;
        let mut at = vec![za; k * m];
        let c0 = group * cg;
        for bi in 0..b {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mm = (bi * oh + oy) * ow + ox;
                    for ky in 0..ksize {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..ksize {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            let src = ((bi * h + iy as usize) * w + ix as usize) * cin + c0;
                            for ci in 0..cg {
                                let kk = (ky * ksize + kx) * cg + ci;
                                at[kk * m + mm] = codes[src + ci];
                            }
                        }
                    }
                }
            }
        }
        (at, k, m, oh * ow)
    }

    fn conv(&mut self, node: &Node, op: &OperatingPoint, x: &Act) -> Result<Act> {
        self.ensure_layer_caches(op, node)?;
        let lp = op
            .params
            .layers
            .get(&node.name)
            .with_context(|| format!("{}: missing params", node.name))?;
        let mid = *op.assignment.get(&node.name).unwrap_or(&0);
        let qin = node.quant_in.context("quant_in")?;
        let qw = node.quant_w.context("quant_w")?;
        let (b, h, w) = (x.shape[0], x.shape[1], x.shape[2]);
        let codes = Self::quantize(&x.data, qin.scale, qin.zero_point);

        let groups = node.groups;
        let cg_out = node.cout / groups;
        let oh = (h + 2 * node.pad - node.ksize) / node.stride + 1;
        let ow = (w + 2 * node.pad - node.ksize) / node.stride + 1;
        let m = b * oh * ow;
        let mut out = vec![0f32; m * node.cout];

        // weight codes: [kh, kw, cin/groups, cout] row-major; per group the
        // output slice is cout columns [g*cg_out, (g+1)*cg_out).
        let kfull = node.ksize * node.ksize * (node.cin / groups);
        let mut acc = vec![0i32; m * cg_out];
        for g in 0..groups {
            let (at, k, m2, _) = Self::im2col_t(
                &codes,
                b,
                h,
                w,
                node.cin,
                node.ksize,
                node.stride,
                node.pad,
                qin.zero_point,
                g,
                groups,
            );
            debug_assert_eq!(k, kfull);
            debug_assert_eq!(m2, m);
            // W^T (cg_out, K) for this group's columns (cached per OP);
            // kernels overwrite `acc`, so one scratch serves every group
            let key = (op.name.clone(), node.name.clone(), g);
            let entry = self.wt_cache.get(&key).context("weight cache")?;
            let (wt, sw) = (&entry.wt, &entry.sw);
            if mid == 0 {
                self.kernel
                    .exact_corrected(&at, wt, m, k, cg_out, qin.zero_point, qw.zero_point, &mut acc);
            } else {
                let wlut = self.wluts[mid].as_ref().unwrap();
                self.kernel.matmul_acc(&at, wt, wlut, m, k, cg_out, &mut acc);
                let sa = lutmm::row_code_sums(&at, m, k);
                lutmm::apply_corrections(&mut acc, &sa, sw, m, k, cg_out, qin.zero_point, qw.zero_point);
            }
            for mm in 0..m {
                for nn in 0..cg_out {
                    let c = g * cg_out + nn;
                    let v = lp.post_scale[c] * acc[mm * cg_out + nn] as f32 + lp.post_bias[c];
                    out[mm * node.cout + c] = node.act.apply(v);
                }
            }
        }
        Ok(Act {
            shape: vec![b, oh, ow, node.cout],
            data: out,
        })
    }

    fn dense(&mut self, node: &Node, op: &OperatingPoint, x: &Act) -> Result<Act> {
        self.ensure_layer_caches(op, node)?;
        let lp = op
            .params
            .layers
            .get(&node.name)
            .with_context(|| format!("{}: missing params", node.name))?;
        let mid = *op.assignment.get(&node.name).unwrap_or(&0);
        let qin = node.quant_in.context("quant_in")?;
        let qw = node.quant_w.context("quant_w")?;
        let b = x.shape[0];
        let k = node.cin;
        let n = node.cout;
        let codes = Self::quantize(&x.data, qin.scale, qin.zero_point);
        // A^T (K, B)
        let mut at = vec![0i32; k * b];
        for bi in 0..b {
            for kk in 0..k {
                at[kk * b + bi] = codes[bi * k + kk];
            }
        }
        // W^T (N, K): weights stored (K, N); cached per OP
        let key = (op.name.clone(), node.name.clone(), 0usize);
        let entry = self.wt_cache.get(&key).context("weight cache")?;
        let (wt, sw) = (&entry.wt, &entry.sw);
        let mut acc = vec![0i32; b * n];
        if mid == 0 {
            self.kernel
                .exact_corrected(&at, wt, b, k, n, qin.zero_point, qw.zero_point, &mut acc);
        } else {
            let wlut = self.wluts[mid].as_ref().unwrap();
            self.kernel.matmul_acc(&at, wt, wlut, b, k, n, &mut acc);
            let sa = lutmm::row_code_sums(&at, b, k);
            lutmm::apply_corrections(&mut acc, &sa, sw, b, k, n, qin.zero_point, qw.zero_point);
        }
        let mut out = vec![0f32; b * n];
        for bi in 0..b {
            for nn in 0..n {
                let v = lp.post_scale[nn] * acc[bi * n + nn] as f32 + lp.post_bias[nn];
                out[bi * n + nn] = node.act.apply(v);
            }
        }
        Ok(Act {
            shape: vec![b, n],
            data: out,
        })
    }
}

// Accuracy evaluation lives in `crate::backend::evaluate`, written once
// against the `Backend` trait so it drives this engine and the PJRT
// runtime through the same code path.
