//! The event taxonomy: everything the serving stack can tell the
//! flight recorder, as one flat enum with a stable JSON encoding.
//!
//! Events are *facts about transitions*, not samples: a batch was
//! formed, a switch completed, a worker moved through the membership
//! machine.  Continuous signals (latency quantiles, queue depth,
//! gauges) live in [`crate::obs::metrics`] instead — the recorder is
//! for reconstructing *why* a transition happened, the registry for
//! watching *what it costs*.
//!
//! [`EventRecord`] wraps an event with the process-monotonic timestamp
//! and the bus sequence number assigned at publish time; the pair is
//! what the flight-recorder dump serializes, and
//! [`EventRecord::from_json`] inverts the encoding exactly (pinned by
//! the round-trip tests in `rust/tests/obs.rs`).

use crate::util::json::Json;

/// One observability event.  String fields hold the stable lowercase
/// encodings the rest of the system already uses (`SwitchMode` as
/// `"drain"`/`"immediate"`, autopilot actions via their `as_str`,
/// membership states via [`crate::obs::member_state_str`]).
#[derive(Debug, Clone, PartialEq)]
pub enum ObsEvent {
    /// The batcher flushed a batch toward the worker pool.
    BatchFormed {
        /// Batcher-assigned batch sequence number.
        batch: u64,
        /// `OpTable` index the batch was stamped with at formation.
        op: usize,
        size: usize,
        /// Tenant class name (`None` = single-tenant, label omitted).
        class: Option<String>,
    },
    /// A pool worker finished a batch (after any retag).
    BatchDone {
        batch: u64,
        /// `OpTable` index the batch actually ran under.
        op: usize,
        size: usize,
        /// Submit-to-done latency of the batch's oldest request.
        latency_us: u64,
        /// Retagged to a cheaper OP at execution time.
        retagged: bool,
        /// Tenant class name (`None` = single-tenant, label omitted).
        class: Option<String>,
    },
    /// The native engine completed one forward pass (kernel span).
    EngineForward {
        /// Operating-point name.
        op: String,
        images: usize,
        dur_us: u64,
    },
    /// The fleet coordinator gathered one chunk from a remote worker.
    FleetChunk {
        addr: String,
        /// `OpTable` index the chunk was forwarded under.
        op: usize,
        images: usize,
        latency_us: u64,
    },
    /// An operating-point switch completed (for `drain` mode this is
    /// published *after* the barrier ack, so event order reflects the
    /// barrier's guarantee).
    OpSwitch {
        /// Destination `OpTable` index.
        op: usize,
        /// `"drain"` or `"immediate"`.
        mode: String,
        /// What drove the switch: `"budget"`, `"autopilot"`,
        /// `"scripted"`, `"operator"`, or `"fleet"` for the
        /// coordinator-side broadcast.
        trigger: String,
        /// Tenant class name (`None` = single-tenant, label omitted).
        class: Option<String>,
    },
    /// One autopilot control tick, with the per-axis actions it chose.
    AutopilotDecision {
        t_s: f64,
        p95_ms: f64,
        /// `OpTable` index after the tick.
        op: usize,
        workers: usize,
        op_action: String,
        pool_action: String,
        chunk_action: String,
        bound: String,
        /// Tenant class name (`None` = single-tenant, label omitted).
        class: Option<String>,
    },
    /// The elastic supervisor changed the pool: `"up"`, `"down"` or
    /// `"spawn_failure"`.
    ScaleAction { action: String, workers: usize },
    /// A fleet worker moved through the membership state machine.
    Membership { addr: String, from: String, to: String },
    /// A heartbeat probe went unanswered.
    HeartbeatMiss { addr: String },
    /// A chunk lost to a transport failure went back on the queue.
    Requeue { images: usize, attempts: usize },
    /// A worker-side drain barrier completed after waiting out its
    /// in-flight forwards.
    WorkerBarrier { waited_us: u64 },
    /// A leveled diagnostic from `obs::log!` (recorded even when the
    /// `QOS_NETS_LOG` gate keeps it off stderr).
    Log { level: String, module: String, message: String },
}

impl ObsEvent {
    /// Stable JSON discriminator for this event.
    pub fn kind(&self) -> &'static str {
        match self {
            ObsEvent::BatchFormed { .. } => "batch_formed",
            ObsEvent::BatchDone { .. } => "batch_done",
            ObsEvent::EngineForward { .. } => "engine_forward",
            ObsEvent::FleetChunk { .. } => "fleet_chunk",
            ObsEvent::OpSwitch { .. } => "op_switch",
            ObsEvent::AutopilotDecision { .. } => "autopilot_decision",
            ObsEvent::ScaleAction { .. } => "scale_action",
            ObsEvent::Membership { .. } => "membership",
            ObsEvent::HeartbeatMiss { .. } => "heartbeat_miss",
            ObsEvent::Requeue { .. } => "requeue",
            ObsEvent::WorkerBarrier { .. } => "worker_barrier",
            ObsEvent::Log { .. } => "log",
        }
    }

    fn fields(&self) -> Vec<(&'static str, Json)> {
        match self {
            ObsEvent::BatchFormed { batch, op, size, class } => {
                let mut fields = vec![
                    ("batch", Json::num(*batch as f64)),
                    ("op", Json::num(*op as f64)),
                    ("size", Json::num(*size as f64)),
                ];
                if let Some(class) = class {
                    fields.push(("class", Json::str(class.clone())));
                }
                fields
            }
            ObsEvent::BatchDone { batch, op, size, latency_us, retagged, class } => {
                let mut fields = vec![
                    ("batch", Json::num(*batch as f64)),
                    ("op", Json::num(*op as f64)),
                    ("size", Json::num(*size as f64)),
                    ("latency_us", Json::num(*latency_us as f64)),
                    ("retagged", Json::Bool(*retagged)),
                ];
                if let Some(class) = class {
                    fields.push(("class", Json::str(class.clone())));
                }
                fields
            }
            ObsEvent::EngineForward { op, images, dur_us } => vec![
                ("op", Json::str(op.clone())),
                ("images", Json::num(*images as f64)),
                ("dur_us", Json::num(*dur_us as f64)),
            ],
            ObsEvent::FleetChunk { addr, op, images, latency_us } => vec![
                ("addr", Json::str(addr.clone())),
                ("op", Json::num(*op as f64)),
                ("images", Json::num(*images as f64)),
                ("latency_us", Json::num(*latency_us as f64)),
            ],
            ObsEvent::OpSwitch { op, mode, trigger, class } => {
                let mut fields = vec![
                    ("op", Json::num(*op as f64)),
                    ("mode", Json::str(mode.clone())),
                    ("trigger", Json::str(trigger.clone())),
                ];
                if let Some(class) = class {
                    fields.push(("class", Json::str(class.clone())));
                }
                fields
            }
            ObsEvent::AutopilotDecision {
                t_s,
                p95_ms,
                op,
                workers,
                op_action,
                pool_action,
                chunk_action,
                bound,
                class,
            } => {
                let mut fields = vec![
                    ("t_s", Json::num(*t_s)),
                    ("p95_ms", Json::num(*p95_ms)),
                    ("op", Json::num(*op as f64)),
                    ("workers", Json::num(*workers as f64)),
                    ("op_action", Json::str(op_action.clone())),
                    ("pool_action", Json::str(pool_action.clone())),
                    ("chunk_action", Json::str(chunk_action.clone())),
                    ("bound", Json::str(bound.clone())),
                ];
                if let Some(class) = class {
                    fields.push(("class", Json::str(class.clone())));
                }
                fields
            }
            ObsEvent::ScaleAction { action, workers } => vec![
                ("action", Json::str(action.clone())),
                ("workers", Json::num(*workers as f64)),
            ],
            ObsEvent::Membership { addr, from, to } => vec![
                ("addr", Json::str(addr.clone())),
                ("from", Json::str(from.clone())),
                ("to", Json::str(to.clone())),
            ],
            ObsEvent::HeartbeatMiss { addr } => vec![("addr", Json::str(addr.clone()))],
            ObsEvent::Requeue { images, attempts } => vec![
                ("images", Json::num(*images as f64)),
                ("attempts", Json::num(*attempts as f64)),
            ],
            ObsEvent::WorkerBarrier { waited_us } => {
                vec![("waited_us", Json::num(*waited_us as f64))]
            }
            ObsEvent::Log { level, module, message } => vec![
                ("level", Json::str(level.clone())),
                ("module", Json::str(module.clone())),
                ("message", Json::str(message.clone())),
            ],
        }
    }

    /// Serialize as a flat object: `{"kind": ..., <fields>}`.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("kind", Json::str(self.kind().to_string()))];
        pairs.extend(self.fields());
        Json::obj(pairs)
    }

    /// Parse the encoding [`to_json`](Self::to_json) produces; unknown
    /// kinds and missing fields are errors (a dump that drifted from
    /// this build's taxonomy should fail loudly, not chart garbage).
    pub fn from_json(v: &Json) -> Result<ObsEvent, String> {
        let f = |key: &str| -> Result<f64, String> {
            v.get(key)
                .and_then(|x| x.as_f64())
                .ok_or_else(|| format!("event: missing or non-numeric {key:?}"))
        };
        let s = |key: &str| -> Result<String, String> {
            v.get(key)
                .and_then(|x| x.as_str())
                .map(str::to_string)
                .ok_or_else(|| format!("event: missing or non-string {key:?}"))
        };
        // lenient: pre-tenancy dumps omit the class label entirely
        let class = || v.get("class").and_then(|x| x.as_str()).map(str::to_string);
        let kind = s("kind")?;
        Ok(match kind.as_str() {
            "batch_formed" => ObsEvent::BatchFormed {
                batch: f("batch")? as u64,
                op: f("op")? as usize,
                size: f("size")? as usize,
                class: class(),
            },
            "batch_done" => ObsEvent::BatchDone {
                batch: f("batch")? as u64,
                op: f("op")? as usize,
                size: f("size")? as usize,
                latency_us: f("latency_us")? as u64,
                retagged: v.get("retagged").and_then(|x| x.as_bool()).unwrap_or(false),
                class: class(),
            },
            "engine_forward" => ObsEvent::EngineForward {
                op: s("op")?,
                images: f("images")? as usize,
                dur_us: f("dur_us")? as u64,
            },
            "fleet_chunk" => ObsEvent::FleetChunk {
                addr: s("addr")?,
                op: f("op")? as usize,
                images: f("images")? as usize,
                latency_us: f("latency_us")? as u64,
            },
            "op_switch" => ObsEvent::OpSwitch {
                op: f("op")? as usize,
                mode: s("mode")?,
                trigger: s("trigger")?,
                class: class(),
            },
            "autopilot_decision" => ObsEvent::AutopilotDecision {
                t_s: f("t_s")?,
                p95_ms: f("p95_ms")?,
                op: f("op")? as usize,
                workers: f("workers")? as usize,
                op_action: s("op_action")?,
                pool_action: s("pool_action")?,
                chunk_action: s("chunk_action")?,
                bound: s("bound")?,
                class: class(),
            },
            "scale_action" => ObsEvent::ScaleAction {
                action: s("action")?,
                workers: f("workers")? as usize,
            },
            "membership" => ObsEvent::Membership {
                addr: s("addr")?,
                from: s("from")?,
                to: s("to")?,
            },
            "heartbeat_miss" => ObsEvent::HeartbeatMiss { addr: s("addr")? },
            "requeue" => ObsEvent::Requeue {
                images: f("images")? as usize,
                attempts: f("attempts")? as usize,
            },
            "worker_barrier" => ObsEvent::WorkerBarrier { waited_us: f("waited_us")? as u64 },
            "log" => ObsEvent::Log {
                level: s("level")?,
                module: s("module")?,
                message: s("message")?,
            },
            other => return Err(format!("event: unknown kind {other:?}")),
        })
    }
}

/// One bus publication: the event plus the publish-time sequence
/// number (total order across the process) and microseconds since the
/// process observability epoch ([`crate::obs::now_us`]).
#[derive(Debug, Clone, PartialEq)]
pub struct EventRecord {
    pub seq: u64,
    pub t_us: u64,
    pub event: ObsEvent,
}

impl EventRecord {
    /// Serialize; [`EventRecord::from_json`] inverts this exactly.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("seq".to_string(), Json::num(self.seq as f64)),
            ("t_us".to_string(), Json::num(self.t_us as f64)),
        ];
        if let Json::Obj(fields) = self.event.to_json() {
            pairs.extend(fields);
        }
        Json::Obj(pairs)
    }

    /// Parse the encoding [`to_json`](Self::to_json) produces.
    pub fn from_json(v: &Json) -> Result<EventRecord, String> {
        let f = |key: &str| -> Result<f64, String> {
            v.get(key)
                .and_then(|x| x.as_f64())
                .ok_or_else(|| format!("event record: missing or non-numeric {key:?}"))
        };
        Ok(EventRecord {
            seq: f("seq")? as u64,
            t_us: f("t_us")? as u64,
            event: ObsEvent::from_json(v)?,
        })
    }
}
