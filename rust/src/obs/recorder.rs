//! The flight recorder: a bounded ring of recent [`EventRecord`]s
//! that snapshots to a versioned JSON dump when something goes wrong.
//!
//! The ring is bounded twice over — by capacity (so a hot serving loop
//! cannot grow it without limit) and by a retention window (so a dump
//! taken after an incident holds the *last N seconds*, not the last N
//! events from twenty minutes ago).  Recording is one short mutex
//! section per event; the recorder only receives events at all while
//! attached to the bus ([`crate::obs::attach_recorder`]), so a serving
//! stack without `--flight-recorder` never pays for it.
//!
//! Dumps are triggered by the serve loop (SLO violation, worker
//! eviction) or by an operator hitting the metrics endpoint's `/dump`
//! route — the std-only stand-in for a `SIGUSR1` handler.

use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::util::json::{self, Json};

use super::event::EventRecord;

/// Bump on any incompatible schema change to the dump JSON.
pub const FLIGHT_DUMP_VERSION: u64 = 1;

/// Default retention window: the last 30 seconds of events.
pub const DEFAULT_WINDOW: Duration = Duration::from_secs(30);

/// Default ring capacity (events, not bytes).
pub const DEFAULT_CAPACITY: usize = 65_536;

/// Bounded last-N-seconds event ring; see the module docs.
pub struct Recorder {
    window_us: u64,
    cap: usize,
    ring: Mutex<VecDeque<EventRecord>>,
}

impl Recorder {
    /// A ring holding at most `cap` events from the last `window`.
    pub fn new(window: Duration, cap: usize) -> Recorder {
        Recorder {
            window_us: window.as_micros() as u64,
            cap: cap.max(1),
            ring: Mutex::new(VecDeque::new()),
        }
    }

    /// A ring with the default window and capacity.
    pub fn with_defaults() -> Recorder {
        Recorder::new(DEFAULT_WINDOW, DEFAULT_CAPACITY)
    }

    /// Append one record, evicting whatever the capacity or the
    /// retention window no longer covers.
    pub fn record(&self, rec: EventRecord) {
        let mut ring = self.ring.lock().unwrap();
        let horizon = rec.t_us.saturating_sub(self.window_us);
        ring.push_back(rec);
        while ring.len() > self.cap {
            ring.pop_front();
        }
        while ring.front().is_some_and(|r| r.t_us < horizon) {
            ring.pop_front();
        }
    }

    /// Events currently held.
    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().len()
    }

    /// Whether the ring is empty.
    pub fn is_empty(&self) -> bool {
        self.ring.lock().unwrap().is_empty()
    }

    /// Copy the ring out, oldest first (the ring keeps recording).
    pub fn snapshot(&self) -> Vec<EventRecord> {
        self.ring.lock().unwrap().iter().cloned().collect()
    }

    /// Freeze the current ring into a dump tagged with `reason`
    /// (`"slo_violation"`, `"eviction"`, `"operator"`, ...).
    pub fn dump(&self, reason: &str) -> FlightDump {
        FlightDump {
            version: FLIGHT_DUMP_VERSION,
            reason: reason.to_string(),
            t_us: super::now_us(),
            events: self.snapshot(),
        }
    }

    /// Dump to `flight_<reason>_<t_us>.json` under `dir`; returns the
    /// path written.
    pub fn dump_to(&self, dir: &Path, reason: &str) -> Result<PathBuf> {
        self.dump(reason).write_to(dir)
    }
}

/// One frozen flight-recorder snapshot, versioned for trend tooling.
#[derive(Debug, Clone, PartialEq)]
pub struct FlightDump {
    pub version: u64,
    /// What triggered the dump.
    pub reason: String,
    /// Dump time, microseconds since the process observability epoch.
    pub t_us: u64,
    /// The retained events, oldest first.
    pub events: Vec<EventRecord>,
}

impl FlightDump {
    /// Serialize; [`FlightDump::from_json`] inverts this exactly.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::num(self.version as f64)),
            ("reason", Json::str(self.reason.clone())),
            ("t_us", Json::num(self.t_us as f64)),
            ("events", Json::Arr(self.events.iter().map(|e| e.to_json()).collect())),
        ])
    }

    /// Parse + validate a dump (strict: wrong version, an unknown
    /// event kind, or any missing required field is an error).
    pub fn from_json(v: &Json) -> Result<FlightDump> {
        let version = v
            .get("version")
            .and_then(|x| x.as_f64())
            .context("flight dump: missing version")? as u64;
        anyhow::ensure!(
            version == FLIGHT_DUMP_VERSION,
            "flight dump version {version} unsupported (this build reads {FLIGHT_DUMP_VERSION})"
        );
        let events = v
            .get("events")
            .and_then(|x| x.as_arr())
            .context("flight dump: missing events array")?
            .iter()
            .map(|e| EventRecord::from_json(e).map_err(|m| anyhow::anyhow!("flight dump: {m}")))
            .collect::<Result<Vec<_>>>()?;
        Ok(FlightDump {
            version,
            reason: v
                .get("reason")
                .and_then(|x| x.as_str())
                .context("flight dump: missing reason")?
                .to_string(),
            t_us: v.get("t_us").and_then(|x| x.as_f64()).context("flight dump: missing t_us")?
                as u64,
            events,
        })
    }

    /// Write to `flight_<reason>_<t_us>.json` under `dir`; returns the
    /// path written.
    pub fn write_to(&self, dir: &Path) -> Result<PathBuf> {
        let safe: String = self
            .reason
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
            .collect();
        let path = dir.join(format!("flight_{safe}_{t}.json", t = self.t_us));
        std::fs::write(&path, json::to_string_pretty(&self.to_json()))
            .with_context(|| format!("writing flight dump to {}", path.display()))?;
        Ok(path)
    }
}
