//! The metrics registry: one process-wide table of metric families
//! rendered in Prometheus text exposition format.
//!
//! Two kinds of series feed it:
//!
//! * **Event-derived counters** — bumped by the bus as cold events
//!   (switches, autopilot decisions, membership transitions, scale
//!   actions, heartbeat misses, log lines) are published.  Their
//!   families are *declared* up front, so `# HELP`/`# TYPE` headers
//!   appear in the exposition even before the first increment — a
//!   scraper can discover the schema on the first scrape.
//! * **Collectors** — closures registered by the serving stack that
//!   read the authoritative sources (`ServerMetrics::snapshot()`,
//!   `FleetStats::snapshot()`, gauges) at scrape time.  Nothing is
//!   double-counted and the hot path pays nothing: quantiles come
//!   from the same `LatencyHistogram::summary()` every report already
//!   uses, so the endpoint and the reports can never disagree.
//!
//! Metric names are part of the public surface; the name table is
//! documented in `docs/ARCHITECTURE.md` and pinned by
//! `rust/tests/obs.rs`.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::Mutex;

use crate::util::stats::LatencySummary;

/// How a family's samples behave over time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Counter,
    Gauge,
}

impl Kind {
    fn as_str(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
        }
    }
}

/// One sample: a label set and a value.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

impl Sample {
    /// An unlabeled sample.
    pub fn plain(value: f64) -> Sample {
        Sample { labels: Vec::new(), value }
    }

    /// A labeled sample.
    pub fn with(labels: &[(&str, &str)], value: f64) -> Sample {
        Sample {
            labels: labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect(),
            value,
        }
    }
}

/// One metric family: a name, its help line, its kind, its samples.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricFamily {
    pub name: String,
    pub help: String,
    pub kind: Kind,
    pub samples: Vec<Sample>,
}

impl MetricFamily {
    /// A family with the given samples.
    pub fn new(name: &str, help: &str, kind: Kind, samples: Vec<Sample>) -> MetricFamily {
        MetricFamily { name: name.to_string(), help: help.to_string(), kind, samples }
    }
}

/// Expand a [`LatencySummary`] into the conventional quantile + count
/// + sum families (`<name>{quantile=...}`, `<name>_count`,
/// `<name>_sum`), all under `extra` labels.  The quantile values are
/// exactly [`LatencySummary`]'s log2-bucket upper bounds — the same
/// numbers every report prints — and the sum is reconstructed from
/// the summary's exact mean.
pub fn summary_families(
    name: &str,
    help: &str,
    extra: &[(&str, &str)],
    s: &LatencySummary,
) -> Vec<MetricFamily> {
    let q = |quantile: &str, v: u64| -> Sample {
        let mut labels = extra.to_vec();
        labels.push(("quantile", quantile));
        Sample::with(&labels, v as f64)
    };
    vec![
        MetricFamily::new(
            name,
            help,
            Kind::Gauge,
            vec![q("0.5", s.p50_us), q("0.95", s.p95_us), q("0.99", s.p99_us)],
        ),
        MetricFamily::new(
            &format!("{name}_count"),
            &format!("Observations behind {name}."),
            Kind::Counter,
            vec![Sample::with(extra, s.count as f64)],
        ),
        MetricFamily::new(
            &format!("{name}_sum"),
            &format!("Sum of observations behind {name}, microseconds."),
            Kind::Counter,
            vec![Sample::with(extra, s.mean_us * s.count as f64)],
        ),
    ]
}

/// The event-derived counter families, declared so their headers
/// render before the first increment.
const DECLARED: &[(&str, &str)] = &[
    ("qos_nets_op_switches_total", "Operating-point switches by mode and trigger."),
    ("qos_nets_autopilot_ticks_total", "Autopilot control ticks by binding constraint."),
    ("qos_nets_autopilot_actions_total", "Autopilot actuations by axis and action."),
    ("qos_nets_scale_events_total", "Elastic-pool scale actions by kind."),
    ("qos_nets_fleet_transitions_total", "Fleet membership transitions by from/to state."),
    ("qos_nets_fleet_heartbeat_misses_total", "Unanswered heartbeat probes by worker."),
    ("qos_nets_fleet_requeues_total", "Chunks requeued after transport failures."),
    ("qos_nets_fleet_evictions_total", "Fleet evictions by worker."),
    ("qos_nets_log_messages_total", "obs::log diagnostics by level."),
    ("qos_nets_flight_dumps_total", "Flight-recorder dumps by trigger reason."),
];

/// A boxed scrape-time collector, as stored in the [`Registry`] (the
/// shape [`Registry::rotate_collectors`] swaps in wholesale).
pub type CollectFn = Box<dyn Fn() -> Vec<MetricFamily> + Send + Sync>;

/// The registry; one per process, via [`crate::obs::registry`].
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, BTreeMap<Vec<(String, String)>, u64>>>,
    collectors: Mutex<Vec<(String, CollectFn)>>,
}

impl Registry {
    /// Register `collect` under `id`, replacing any collector already
    /// registered under the same id (so a bench harness re-running
    /// passes swaps sources instead of stacking them).
    pub fn register<F>(&self, id: &str, collect: F)
    where
        F: Fn() -> Vec<MetricFamily> + Send + Sync + 'static,
    {
        let mut cs = self.collectors.lock().unwrap();
        cs.retain(|(cid, _)| cid != id);
        cs.push((id.to_string(), Box::new(collect)));
    }

    /// Drop the collector registered under `id` (no-op if absent).
    pub fn unregister(&self, id: &str) {
        self.collectors.lock().unwrap().retain(|(cid, _)| cid != id);
    }

    /// Bump an event-derived counter.  `name` should be one of the
    /// declared families so its header renders; undeclared names still
    /// count but expose without a help line.
    pub fn inc(&self, name: &str, labels: &[(&str, &str)], by: u64) {
        let key: Vec<(String, String)> =
            labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        let mut c = self.counters.lock().unwrap();
        *c.entry(name.to_string()).or_default().entry(key).or_insert(0) += by;
    }

    /// Zero every event-derived counter (the bench harness calls this
    /// between paired passes so the endpoint reflects the current
    /// pass; collectors re-register instead).
    pub fn reset_counters(&self) {
        self.counters.lock().unwrap().clear();
    }

    /// Zero every event-derived counter AND swap in a fresh collector
    /// set in one critical section.  Rotating one source at a time
    /// (`reset_counters` + per-id `register` calls) leaves a window
    /// where a scrape pairs the previous pass's per-OP families with
    /// the next pass's zeroed counters; the bench harness uses this
    /// between paired passes so a scrape sees the old sources or the
    /// new ones, never a mix.  Collectors named in `fresh` replace any
    /// same-id entry; other registered collectors are left in place.
    pub fn rotate_collectors(&self, fresh: Vec<(String, CollectFn)>) {
        let mut counters = self.counters.lock().unwrap();
        let mut cs = self.collectors.lock().unwrap();
        counters.clear();
        for (id, collect) in fresh {
            cs.retain(|(cid, _)| cid != &id);
            cs.push((id, collect));
        }
    }

    /// Materialize every family: declared counters (with whatever
    /// counts exist), then collector output, merged by name and
    /// sorted.
    pub fn gather(&self) -> Vec<MetricFamily> {
        let mut by_name: BTreeMap<String, MetricFamily> = BTreeMap::new();
        for (name, help) in DECLARED {
            by_name.insert(
                name.to_string(),
                MetricFamily::new(name, help, Kind::Counter, Vec::new()),
            );
        }
        {
            let counters = self.counters.lock().unwrap();
            for (name, series) in counters.iter() {
                let fam = by_name.entry(name.clone()).or_insert_with(|| {
                    MetricFamily::new(name, "", Kind::Counter, Vec::new())
                });
                for (labels, value) in series {
                    fam.samples.push(Sample { labels: labels.clone(), value: *value as f64 });
                }
            }
        }
        let collectors = self.collectors.lock().unwrap();
        for (_, collect) in collectors.iter() {
            for fam in collect() {
                match by_name.get_mut(&fam.name) {
                    Some(existing) => existing.samples.extend(fam.samples),
                    None => {
                        by_name.insert(fam.name.clone(), fam);
                    }
                }
            }
        }
        by_name.into_values().collect()
    }

    /// Render the whole registry in Prometheus text exposition format
    /// (text/plain; version=0.0.4): deterministic family order, one
    /// `# HELP`/`# TYPE` header per family.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for fam in self.gather() {
            if !fam.help.is_empty() {
                let _ = writeln!(out, "# HELP {} {}", fam.name, escape_help(&fam.help));
            }
            let _ = writeln!(out, "# TYPE {} {}", fam.name, fam.kind.as_str());
            for s in &fam.samples {
                if s.labels.is_empty() {
                    let _ = writeln!(out, "{} {}", fam.name, fmt_value(s.value));
                } else {
                    let labels: Vec<String> = s
                        .labels
                        .iter()
                        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
                        .collect();
                    let joined = labels.join(",");
                    let _ = writeln!(out, "{}{{{joined}}} {}", fam.name, fmt_value(s.value));
                }
            }
        }
        out
    }

    /// Look one sample up by family name and exact label set (order
    /// insensitive) — what the live dashboard reads, so the panel and
    /// the exposition endpoint share one source.
    pub fn value(&self, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
        let mut want: Vec<(String, String)> =
            labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        want.sort();
        for fam in self.gather() {
            if fam.name != name {
                continue;
            }
            for s in &fam.samples {
                let mut have = s.labels.clone();
                have.sort();
                if have == want {
                    return Some(s.value);
                }
            }
        }
        None
    }
}

fn fmt_value(v: f64) -> String {
    if v.is_finite() && v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn escape_help(v: &str) -> String {
    v.replace('\\', "\\\\").replace('\n', "\\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn declared_families_render_headers_before_any_increment() {
        let r = Registry::default();
        let text = r.render();
        for (name, _) in DECLARED {
            assert!(
                text.contains(&format!("# TYPE {name} counter")),
                "missing declared header for {name}"
            );
        }
    }

    #[test]
    fn counters_merge_into_their_declared_family() {
        let r = Registry::default();
        r.inc("qos_nets_op_switches_total", &[("mode", "drain"), ("trigger", "budget")], 1);
        r.inc("qos_nets_op_switches_total", &[("mode", "drain"), ("trigger", "budget")], 2);
        let text = r.render();
        assert!(
            text.contains("qos_nets_op_switches_total{mode=\"drain\",trigger=\"budget\"} 3"),
            "{text}"
        );
        // exactly one header for the family
        assert_eq!(text.matches("# TYPE qos_nets_op_switches_total").count(), 1);
        assert_eq!(
            r.value("qos_nets_op_switches_total", &[("trigger", "budget"), ("mode", "drain")]),
            Some(3.0)
        );
    }

    #[test]
    fn collectors_replace_by_id_and_merge_by_family() {
        let r = Registry::default();
        r.register("g", || {
            vec![MetricFamily::new("demo_gauge", "a demo", Kind::Gauge, vec![Sample::plain(1.0)])]
        });
        r.register("g", || {
            vec![MetricFamily::new("demo_gauge", "a demo", Kind::Gauge, vec![Sample::plain(2.0)])]
        });
        assert_eq!(r.value("demo_gauge", &[]), Some(2.0));
        r.unregister("g");
        assert_eq!(r.value("demo_gauge", &[]), None);
    }

    #[test]
    fn label_values_are_escaped() {
        let r = Registry::default();
        r.inc("weird", &[("addr", "a\"b\\c")], 1);
        assert!(r.render().contains("weird{addr=\"a\\\"b\\\\c\"} 1"));
    }

    #[test]
    fn summary_families_mirror_the_latency_summary() {
        let s = LatencySummary {
            count: 10,
            mean_us: 150.0,
            p50_us: 128,
            p95_us: 256,
            p99_us: 512,
            max_us: 400,
        };
        let fams = summary_families("lat_us", "demo", &[("op", "exact")], &s);
        assert_eq!(fams.len(), 3);
        let q = &fams[0];
        assert_eq!(q.samples[0].value, 128.0);
        assert_eq!(q.samples[2].value, 512.0);
        assert_eq!(fams[1].samples[0].value, 10.0);
        assert!((fams[2].samples[0].value - 1500.0).abs() < 1e-9);
    }
}
