//! Unified observability: one event bus, a flight recorder, and a
//! scrapeable metrics registry across server, fleet, autopilot and
//! engine.
//!
//! The paper's runtime-adaptation story only pays off if an operator
//! can see *why* each transition happened and *what it cost*.  Before
//! this module that evidence was fragmented across
//! `ServerMetrics::snapshot()`, `FleetStats`, autopilot decision logs
//! and ad-hoc stderr prints; here it converges on three std-only
//! pieces:
//!
//! * **The event bus** ([`publish`], [`ObsEvent`]): subsystems publish
//!   transition facts — batch lifecycle spans, OP switches with mode +
//!   trigger, autopilot decisions, scale actions, fleet membership
//!   transitions, heartbeat misses, requeues.  The fast path is two
//!   relaxed atomic loads when nothing is attached, so library code
//!   publishes unconditionally from hot loops without checking flags.
//!   Hot span events that would allocate should still gate on
//!   [`recording`] at the call site to skip building the event at all.
//! * **The flight recorder** ([`recorder::Recorder`]): a bounded ring
//!   of the last N seconds of events, attached to the bus with
//!   [`attach_recorder`], frozen to a versioned JSON dump on SLO
//!   violation, worker eviction, or operator request (`serve
//!   --flight-recorder`, `GET /dump` on the metrics endpoint).
//! * **The metrics registry** ([`metrics::Registry`], [`registry`]):
//!   event-derived counters plus scrape-time collectors over the
//!   authoritative snapshots, rendered in Prometheus text format by
//!   the std-only TCP endpoint in [`http`] (`serve --metrics-addr`).
//!
//! Leveled diagnostics ride the same bus: the [`log!`](crate::obs_log)
//! macro gates stderr output on `QOS_NETS_LOG` (error/warn/info/debug,
//! default `warn`) and publishes every message as an
//! [`ObsEvent::Log`], so a flight dump carries the warnings that led
//! up to an incident even when they never hit the terminal.

pub mod event;
pub mod http;
pub mod metrics;
pub mod recorder;

pub use event::{EventRecord, ObsEvent};
pub use http::MetricsServer;
pub use metrics::Registry;
pub use recorder::{FlightDump, Recorder, FLIGHT_DUMP_VERSION};

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock, RwLock};
use std::time::Instant;

// ---------------------------------------------------------------------------
// Monotonic timestamps and sequence numbers
// ---------------------------------------------------------------------------

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds since the process observability epoch (the first call
/// into this module).  Monotonic; shared by every event timestamp.
pub fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

static SEQ: AtomicU64 = AtomicU64::new(0);

// ---------------------------------------------------------------------------
// The bus
// ---------------------------------------------------------------------------

static RECORDER_COUNT: AtomicUsize = AtomicUsize::new(0);

fn recorders() -> &'static RwLock<Vec<Arc<Recorder>>> {
    static RECORDERS: OnceLock<RwLock<Vec<Arc<Recorder>>>> = OnceLock::new();
    RECORDERS.get_or_init(|| RwLock::new(Vec::new()))
}

/// Whether any flight recorder is attached.  Hot publish sites that
/// would allocate to build their event (names, addresses) should gate
/// on this so a stack without a recorder pays a single atomic load.
pub fn recording() -> bool {
    RECORDER_COUNT.load(Ordering::Relaxed) > 0
}

/// Attach a recorder: every subsequent [`publish`] lands in it.
pub fn attach_recorder(r: Arc<Recorder>) {
    recorders().write().unwrap().push(r);
    RECORDER_COUNT.fetch_add(1, Ordering::Relaxed);
}

/// Detach a previously attached recorder (matched by identity).
pub fn detach_recorder(r: &Arc<Recorder>) {
    let mut subs = recorders().write().unwrap();
    let before = subs.len();
    subs.retain(|s| !Arc::ptr_eq(s, r));
    let removed = before - subs.len();
    if removed > 0 {
        RECORDER_COUNT.fetch_sub(removed, Ordering::Relaxed);
    }
}

/// The process-wide metrics registry.
pub fn registry() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(Registry::default)
}

/// Publish one event: bump its event-derived counters (cold kinds
/// only — span events are counted by the collectors that already own
/// their sources) and, when a recorder is attached, stamp a sequence
/// number + monotonic timestamp and append to every ring.
pub fn publish(event: ObsEvent) {
    bump_counters(&event);
    if recording() {
        let rec = EventRecord { seq: SEQ.fetch_add(1, Ordering::Relaxed), t_us: now_us(), event };
        for r in recorders().read().unwrap().iter() {
            r.record(rec.clone());
        }
    }
}

fn bump_counters(event: &ObsEvent) {
    let reg = registry();
    match event {
        ObsEvent::OpSwitch { mode, trigger, class, .. } => {
            // the class label rides along only when the event carries
            // one, so single-tenant series keep their pre-tenancy names
            match class {
                Some(c) => reg.inc(
                    "qos_nets_op_switches_total",
                    &[("class", c), ("mode", mode), ("trigger", trigger)],
                    1,
                ),
                None => {
                    reg.inc("qos_nets_op_switches_total", &[("mode", mode), ("trigger", trigger)], 1)
                }
            }
        }
        ObsEvent::AutopilotDecision { op_action, pool_action, chunk_action, bound, class, .. } => {
            match class {
                Some(c) => {
                    reg.inc("qos_nets_autopilot_ticks_total", &[("bound", bound), ("class", c)], 1)
                }
                None => reg.inc("qos_nets_autopilot_ticks_total", &[("bound", bound)], 1),
            }
            for (axis, action) in
                [("op", op_action), ("pool", pool_action), ("chunk", chunk_action)]
            {
                if action != "none" {
                    reg.inc(
                        "qos_nets_autopilot_actions_total",
                        &[("axis", axis), ("action", action)],
                        1,
                    );
                }
            }
        }
        ObsEvent::ScaleAction { action, .. } => {
            reg.inc("qos_nets_scale_events_total", &[("action", action)], 1);
        }
        ObsEvent::Membership { addr, from, to } => {
            reg.inc("qos_nets_fleet_transitions_total", &[("from", from), ("to", to)], 1);
            if to == "evicted" {
                reg.inc("qos_nets_fleet_evictions_total", &[("addr", addr)], 1);
            }
        }
        ObsEvent::HeartbeatMiss { addr } => {
            reg.inc("qos_nets_fleet_heartbeat_misses_total", &[("addr", addr)], 1);
        }
        ObsEvent::Requeue { .. } => {
            reg.inc("qos_nets_fleet_requeues_total", &[], 1);
        }
        ObsEvent::Log { level, .. } => {
            reg.inc("qos_nets_log_messages_total", &[("level", level)], 1);
        }
        // span events: counted at their authoritative sources
        ObsEvent::BatchFormed { .. }
        | ObsEvent::BatchDone { .. }
        | ObsEvent::EngineForward { .. }
        | ObsEvent::FleetChunk { .. }
        | ObsEvent::WorkerBarrier { .. } => {}
    }
}

/// Record a flight dump being taken (counter + recorder trace).
pub fn note_flight_dump(reason: &str) {
    registry().inc("qos_nets_flight_dumps_total", &[("reason", reason)], 1);
}

// ---------------------------------------------------------------------------
// Leveled logging (`obs::log!`, gated by QOS_NETS_LOG)
// ---------------------------------------------------------------------------

/// Diagnostic severity for [`log!`](crate::obs_log), ordered from
/// most to least severe.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    /// Lowercase name (the `QOS_NETS_LOG` value and the counter label).
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

/// `QOS_NETS_LOG` threshold: messages at or above this severity go to
/// stderr.  `off` silences stderr entirely (events still publish).
fn log_threshold() -> i8 {
    static THRESHOLD: OnceLock<i8> = OnceLock::new();
    *THRESHOLD.get_or_init(|| {
        let raw = std::env::var("QOS_NETS_LOG").unwrap_or_default();
        match raw.trim().to_ascii_lowercase().as_str() {
            "off" | "none" => -1,
            "error" => Level::Error as i8,
            "info" => Level::Info as i8,
            "debug" => Level::Debug as i8,
            // default: warnings and errors, matching the pre-obs
            // behavior of the library's eprintln! diagnostics
            _ => Level::Warn as i8,
        }
    })
}

/// Whether a message at `level` would reach stderr.
pub fn log_enabled(level: Level) -> bool {
    (level as i8) <= log_threshold()
}

/// The implementation behind [`log!`](crate::obs_log): print to
/// stderr when the `QOS_NETS_LOG` gate allows it, and publish the
/// message onto the bus either way (so flight dumps keep suppressed
/// diagnostics).  CLI user-facing output stays on plain
/// `println!`/`eprintln!` — this is for *library* diagnostics only.
pub fn logf(level: Level, module: &str, args: std::fmt::Arguments<'_>) {
    let message = args.to_string();
    if log_enabled(level) {
        eprintln!("[{}] {module}: {message}", level.as_str());
    }
    publish(ObsEvent::Log {
        level: level.as_str().to_string(),
        module: module.to_string(),
        message,
    });
}

/// Leveled library diagnostic: `obs::log!(Warn, "chunk {n} requeued")`.
///
/// The level is a [`Level`] variant name; the rest is `format!`
/// syntax.  Messages print to stderr as `[warn] module::path: ...`
/// when `QOS_NETS_LOG` allows the level (default `warn`), and always
/// publish as [`ObsEvent::Log`] for the flight recorder.
#[macro_export]
macro_rules! obs_log {
    ($lvl:ident, $($arg:tt)*) => {
        $crate::obs::logf(
            $crate::obs::Level::$lvl,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

pub use crate::obs_log as log;

/// Stable lowercase encoding of a fleet membership state for events
/// and metric labels.
pub fn member_state_str(state: crate::fleet::MemberState) -> &'static str {
    match state {
        crate::fleet::MemberState::Live => "live",
        crate::fleet::MemberState::Suspect => "suspect",
        crate::fleet::MemberState::Evicted => "evicted",
        crate::fleet::MemberState::Rejoining => "rejoining",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn publish_is_inert_without_recorders_but_counts_cold_events() {
        let before = registry()
            .value("qos_nets_op_switches_total", &[("mode", "drain"), ("trigger", "test-inert")])
            .unwrap_or(0.0);
        publish(ObsEvent::OpSwitch {
            op: 1,
            mode: "drain".into(),
            trigger: "test-inert".into(),
            class: None,
        });
        let after = registry()
            .value("qos_nets_op_switches_total", &[("mode", "drain"), ("trigger", "test-inert")])
            .unwrap();
        assert_eq!(after, before + 1.0);
    }

    #[test]
    fn attached_recorder_sees_events_in_seq_order() {
        let r = Arc::new(Recorder::new(Duration::from_secs(60), 128));
        attach_recorder(r.clone());
        publish(ObsEvent::HeartbeatMiss { addr: "t:1".into() });
        publish(ObsEvent::HeartbeatMiss { addr: "t:2".into() });
        detach_recorder(&r);
        publish(ObsEvent::HeartbeatMiss { addr: "t:3".into() });
        let events: Vec<EventRecord> = r
            .snapshot()
            .into_iter()
            .filter(|e| {
                matches!(&e.event, ObsEvent::HeartbeatMiss { addr } if addr.starts_with("t:"))
            })
            .collect();
        assert_eq!(events.len(), 2, "detached recorder must stop receiving");
        assert!(events[0].seq < events[1].seq);
        assert!(events[0].t_us <= events[1].t_us);
    }

    #[test]
    fn log_levels_parse_and_order() {
        assert!(Level::Error < Level::Debug);
        assert_eq!(Level::Warn.as_str(), "warn");
    }
}
