//! A tiny std-only HTTP endpoint exposing the metrics registry.
//!
//! One accept thread, blocking reads with a short timeout, two routes:
//!
//! * `GET /metrics` — the registry rendered in Prometheus text format
//!   (`text/plain; version=0.0.4`), gathered fresh per scrape.
//! * `GET /dump` — freeze the attached flight recorder to JSON and
//!   return it (the operator-request dump trigger; 404 when no
//!   recorder is attached).
//!
//! This is deliberately not a web server: no keep-alive, no routing
//! table, no TLS — just enough HTTP/1.1 for `curl` and a Prometheus
//! scraper, with zero new dependencies.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::util::json;

use super::recorder::Recorder;

/// Scrape endpoint serving the process-wide [`super::registry`].
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9100`, or `127.0.0.1:0` for an
    /// ephemeral port) and start serving scrapes on a background
    /// thread.  `recorder`, when given, backs the `/dump` route.
    pub fn start(addr: &str, recorder: Option<Arc<Recorder>>) -> Result<MetricsServer> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("metrics endpoint: binding {addr}"))?;
        let local = listener.local_addr().context("metrics endpoint: local_addr")?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = stop.clone();
        let handle = std::thread::Builder::new()
            .name("obs-metrics".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop_flag.load(Ordering::Relaxed) {
                        break;
                    }
                    match conn {
                        Ok(stream) => handle_conn(stream, recorder.as_deref()),
                        Err(_) => continue,
                    }
                }
            })
            .context("metrics endpoint: spawning accept thread")?;
        Ok(MetricsServer { addr: local, stop, handle: Some(handle) })
    }

    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the accept loop and join the thread.
    pub fn shutdown(&mut self) {
        if self.handle.is_none() {
            return;
        }
        self.stop.store(true, Ordering::Relaxed);
        // Wake the blocking accept with a throwaway connection.
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(200));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn handle_conn(mut stream: TcpStream, recorder: Option<&Recorder>) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(2)));
    let mut buf = [0u8; 2048];
    let mut req = Vec::new();
    // Read until the end of the request head (we ignore any body).
    loop {
        match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => {
                req.extend_from_slice(&buf[..n]);
                if req.windows(4).any(|w| w == b"\r\n\r\n") || req.len() > 16 * 1024 {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    let head = String::from_utf8_lossy(&req);
    let path = head.split_whitespace().nth(1).unwrap_or("");
    let (status, content_type, body) = match path {
        "/metrics" | "/" => (
            "200 OK",
            "text/plain; version=0.0.4; charset=utf-8",
            super::registry().render(),
        ),
        "/dump" => match recorder {
            Some(r) => {
                super::note_flight_dump("operator");
                let dump = r.dump("operator");
                ("200 OK", "application/json", json::to_string_pretty(&dump.to_json()))
            }
            None => (
                "404 Not Found",
                "text/plain; charset=utf-8",
                "no flight recorder attached\n".to_string(),
            ),
        },
        _ => ("404 Not Found", "text/plain; charset=utf-8", "not found\n".to_string()),
    };
    let _ = write!(
        stream,
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {len}\r\nConnection: close\r\n\r\n",
        len = body.len()
    );
    let _ = stream.write_all(body.as_bytes());
    let _ = stream.flush();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::ObsEvent;

    fn get(addr: SocketAddr, path: &str) -> (String, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        let (head, body) = out.split_once("\r\n\r\n").unwrap_or((out.as_str(), ""));
        (head.to_string(), body.to_string())
    }

    #[test]
    fn metrics_route_serves_prometheus_text() {
        let mut srv = MetricsServer::start("127.0.0.1:0", None).unwrap();
        let (head, body) = get(srv.local_addr(), "/metrics");
        assert!(head.starts_with("HTTP/1.1 200"), "head: {head}");
        assert!(head.contains("text/plain; version=0.0.4"), "head: {head}");
        assert!(body.contains("# TYPE qos_nets_op_switches_total counter"), "body: {body}");
        srv.shutdown();
    }

    #[test]
    fn dump_route_404s_without_recorder_and_serves_json_with_one() {
        let mut srv = MetricsServer::start("127.0.0.1:0", None).unwrap();
        let (head, _) = get(srv.local_addr(), "/dump");
        assert!(head.starts_with("HTTP/1.1 404"), "head: {head}");
        srv.shutdown();

        let rec = Arc::new(Recorder::with_defaults());
        crate::obs::attach_recorder(rec.clone());
        crate::obs::publish(crate::obs::ObsEvent::HeartbeatMiss { addr: "dump-test:1".into() });
        let mut srv = MetricsServer::start("127.0.0.1:0", Some(rec.clone())).unwrap();
        let (head, body) = get(srv.local_addr(), "/dump");
        crate::obs::detach_recorder(&rec);
        assert!(head.starts_with("HTTP/1.1 200"), "head: {head}");
        let parsed = crate::util::json::parse(&body).unwrap();
        let dump = crate::obs::FlightDump::from_json(&parsed).unwrap();
        assert_eq!(dump.reason, "operator");
        let hit = dump.events.iter().any(|e| {
            matches!(&e.event, ObsEvent::HeartbeatMiss { addr } if addr == "dump-test:1")
        });
        assert!(hit, "recorded heartbeat miss missing from the dump");
        srv.shutdown();
    }

    #[test]
    fn unknown_route_404s() {
        let mut srv = MetricsServer::start("127.0.0.1:0", None).unwrap();
        let (head, _) = get(srv.local_addr(), "/nope");
        assert!(head.starts_with("HTTP/1.1 404"));
        srv.shutdown();
    }
}
