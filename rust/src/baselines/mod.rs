//! Baseline mapping algorithms the paper compares against (Table 1).
//!
//! All baselines consume the same inputs as the QoS-Nets search (the
//! sigma_e error-model matrix, the sigma_g tolerance vector, layer MAC
//! statistics and the multiplier power model) and emit layer->multiplier
//! assignments, so every method is evaluated through the identical
//! retraining + engine-evaluation path — the honest comparison the paper
//! tables rely on.
//!
//! LVRM [15] and PNAM [14] natively operate at *value-range* granularity
//! inside a single reconfigurable multiplier; our accelerator model (like
//! ALWANN's) dispatches per layer, so we implement faithful layer-
//! granularity analogues of their mapping strategies (documented in
//! DESIGN.md; the paper itself quotes the published numbers rather than
//! re-running those systems).

pub mod alwann;

use crate::errmodel::{relative_power, SigmaE};
use crate::muldb::MulDb;
use crate::nn::LayerStats;

/// Quality proxy for an assignment: mean squared tolerance violation.
/// 0 when every layer's multiplier meets its sigma_g budget; grows
/// quadratically with excess noise (the same aggregation the genetic
/// baseline optimizes against).
pub fn quality_penalty(se: &SigmaE, sigma_g: &[f64], assignment: &[usize]) -> f64 {
    assignment
        .iter()
        .enumerate()
        .map(|(k, &j)| {
            let r = se.get(j, k) / sigma_g[k].max(1e-12);
            let excess = (r - 1.0).max(0.0);
            excess * excess
        })
        .sum::<f64>()
        / assignment.len() as f64
}

/// Homogeneous deployment [De la Parra et al. 2020]: one multiplier for
/// the whole network.  Returns the per-multiplier (power, penalty) sweep;
/// the caller picks instances near a power target.
pub fn homogeneous_sweep(
    db: &MulDb,
    se: &SigmaE,
    sigma_g: &[f64],
    stats: &[LayerStats],
) -> Vec<(usize, f64, f64)> {
    (0..db.len())
        .map(|j| {
            let assignment = vec![j; se.l];
            (
                j,
                relative_power(db, stats, &assignment),
                quality_penalty(se, sigma_g, &assignment),
            )
        })
        .collect()
}

/// Pick the homogeneous instance with the lowest power among those whose
/// penalty does not exceed `max_penalty`.
pub fn homogeneous_pick(
    db: &MulDb,
    se: &SigmaE,
    sigma_g: &[f64],
    stats: &[LayerStats],
    max_penalty: f64,
) -> usize {
    homogeneous_sweep(db, se, sigma_g, stats)
        .into_iter()
        .filter(|(_, _, pen)| *pen <= max_penalty)
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .map(|(j, _, _)| j)
        .unwrap_or(0)
}

/// Unconstrained Gradient Search [Trommer et al. 2022, ICCAD]: per layer,
/// among multipliers with sigma_e <= scale * sigma_g, pick the one with
/// the lowest power.  No cross-layer constraint — the solution may use up
/// to min(m, l) distinct instances (the impracticality QoS-Nets fixes).
pub fn gradient_search(
    db: &MulDb,
    se: &SigmaE,
    sigma_g: &[f64],
    scale: f64,
) -> Vec<usize> {
    (0..se.l)
        .map(|k| {
            let tol = scale * sigma_g[k];
            (0..se.m)
                .filter(|&j| se.get(j, k) <= tol)
                .min_by(|&a, &b| db.power(a).partial_cmp(&db.power(b)).unwrap())
                .unwrap_or(0) // exact multiplier always qualifies (sigma_e = 0)
        })
        .collect()
}

/// LVRM-style divide & conquer at layer granularity: recursively split
/// the layer range; for each segment try the cheapest single multiplier
/// that keeps the segment's aggregate penalty at zero; recurse when no
/// non-exact instance qualifies for the whole segment.
pub fn lvrm_divide_conquer(
    db: &MulDb,
    se: &SigmaE,
    sigma_g: &[f64],
    scale: f64,
) -> Vec<usize> {
    let mut assignment = vec![0usize; se.l];
    fn solve(
        db: &MulDb,
        se: &SigmaE,
        sigma_g: &[f64],
        scale: f64,
        lo: usize,
        hi: usize,
        out: &mut Vec<usize>,
    ) {
        // cheapest instance that satisfies every layer in [lo, hi)
        let pick = (0..se.m)
            .filter(|&j| (lo..hi).all(|k| se.get(j, k) <= scale * sigma_g[k]))
            .min_by(|&a, &b| db.power(a).partial_cmp(&db.power(b)).unwrap());
        match pick {
            Some(j) if hi - lo == 1 || j != 0 => {
                for k in lo..hi {
                    out[k] = j;
                }
            }
            _ => {
                let mid = (lo + hi) / 2;
                solve(db, se, sigma_g, scale, lo, mid, out);
                solve(db, se, sigma_g, scale, mid, hi, out);
            }
        }
    }
    solve(db, se, sigma_g, scale, 0, se.l, &mut assignment);
    assignment
}

/// PNAM-style positive/negative pairing at layer granularity: greedily
/// walk the layers, tracking the running systematic error mean; at every
/// layer prefer the cheapest tolerance-respecting instance whose error
/// mean *opposes* the accumulated mean (the positive/negative-multiplier
/// cancellation idea of Spantidi et al.).
pub fn pnam_mapping(
    db: &MulDb,
    se: &SigmaE,
    sigma_g: &[f64],
    stats: &[LayerStats],
    scale: f64,
) -> Vec<usize> {
    let mut acc_mean = 0.0f64;
    let mut out = Vec::with_capacity(se.l);
    for k in 0..se.l {
        let tol = scale * sigma_g[k];
        let candidates: Vec<usize> = (0..se.m).filter(|&j| se.get(j, k) <= tol).collect();
        let best = candidates
            .iter()
            .map(|&j| {
                let mean = crate::errmodel::error_mean(db, j, &stats[k]);
                // lexicographic-ish score: cancellation first, power second
                let cancel = (acc_mean + mean).abs();
                (j, mean, cancel + db.power(j) * 1e-3)
            })
            .min_by(|a, b| a.2.partial_cmp(&b.2).unwrap())
            .map(|(j, mean, _)| (j, mean))
            .unwrap_or((0, 0.0));
        acc_mean += best.1;
        out.push(best.0);
    }
    out
}

/// TPM-style threshold query (Spantidi et al., PSTL): binary-search a
/// global error-std threshold theta; each layer takes the cheapest
/// instance with sigma_e <= theta * sigma_g; the largest theta whose
/// total penalty stays zero wins.  Produces one conservative, globally
/// thresholded solution (the method's hallmark low power reduction).
pub fn tpm_threshold(db: &MulDb, se: &SigmaE, sigma_g: &[f64], scale: f64) -> Vec<usize> {
    let assign_at = |theta: f64| -> Vec<usize> {
        (0..se.l)
            .map(|k| {
                (0..se.m)
                    .filter(|&j| se.get(j, k) <= theta * scale * sigma_g[k])
                    .min_by(|&a, &b| db.power(a).partial_cmp(&db.power(b)).unwrap())
                    .unwrap_or(0)
            })
            .collect()
    };
    let mut lo = 0.0f64;
    let mut hi = 1.0f64;
    for _ in 0..24 {
        let mid = 0.5 * (lo + hi);
        let a = assign_at(mid);
        if quality_penalty(se, sigma_g, &a) <= 0.0 {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    assign_at(lo)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::errmodel::sigma_e;

    fn setup() -> (MulDb, SigmaE, Vec<f64>, Vec<LayerStats>) {
        let db = MulDb::generate();
        let stats: Vec<LayerStats> = (0..8)
            .map(|i| LayerStats {
                name: format!("l{i}"),
                act_hist: vec![1.0 / 256.0; 256],
                w_hist: vec![1.0 / 256.0; 256],
                k_fanin: 32 << (i % 4),
                macs_total: 50_000,
                s_act: 0.02,
                z_act: 128,
                s_w: 0.01,
                z_w: 128,
                bn_scale: 0.3,
                out_rms: 1.0,
            })
            .collect();
        let se = sigma_e(&db, &stats);
        let sigma_g: Vec<f64> = (0..8).map(|i| 0.05 * (1.0 + i as f64)).collect();
        (db, se, sigma_g, stats)
    }

    #[test]
    fn gradient_search_respects_tolerance() {
        let (db, se, sigma_g, _) = setup();
        let a = gradient_search(&db, &se, &sigma_g, 1.0);
        for (k, &j) in a.iter().enumerate() {
            assert!(se.get(j, k) <= sigma_g[k] + 1e-12, "layer {k} mul {j}");
        }
    }

    #[test]
    fn gradient_search_zero_penalty() {
        let (db, se, sigma_g, _) = setup();
        let a = gradient_search(&db, &se, &sigma_g, 1.0);
        assert_eq!(quality_penalty(&se, &sigma_g, &a), 0.0);
    }

    #[test]
    fn homogeneous_exact_has_zero_penalty_and_unit_power() {
        let (db, se, sigma_g, stats) = setup();
        let sweep = homogeneous_sweep(&db, &se, &sigma_g, &stats);
        let exact = sweep.iter().find(|(j, _, _)| *j == 0).unwrap();
        assert!((exact.1 - 1.0).abs() < 1e-12);
        assert_eq!(exact.2, 0.0);
    }

    #[test]
    fn lvrm_never_violates_budget() {
        let (db, se, sigma_g, _) = setup();
        let a = lvrm_divide_conquer(&db, &se, &sigma_g, 1.0);
        assert_eq!(quality_penalty(&se, &sigma_g, &a), 0.0);
    }

    #[test]
    fn tpm_is_conservative() {
        let (db, se, sigma_g, stats) = setup();
        let a = tpm_threshold(&db, &se, &sigma_g, 1.0);
        assert_eq!(quality_penalty(&se, &sigma_g, &a), 0.0);
        // conservative: no cheaper than unconstrained gradient search
        let g = gradient_search(&db, &se, &sigma_g, 1.0);
        let pa = relative_power(&db, &stats, &a);
        let pg = relative_power(&db, &stats, &g);
        assert!(pa >= pg - 1e-9, "tpm {pa} vs gradient {pg}");
    }

    #[test]
    fn pnam_respects_tolerance() {
        let (db, se, sigma_g, stats) = setup();
        let a = pnam_mapping(&db, &se, &sigma_g, &stats, 1.0);
        assert_eq!(quality_penalty(&se, &sigma_g, &a), 0.0);
    }
}
