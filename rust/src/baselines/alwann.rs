//! ALWANN-style genetic tile mapping [Mrazek et al., ICCAD 2019].
//!
//! The accelerator has `n_tiles` compute tiles, each built from one
//! multiplier instance.  A chromosome is (tile multiplier ids, layer ->
//! tile map).  NSGA-II-lite multi-objective evolution over (relative
//! power, quality penalty); returns the final nondominated front so the
//! caller can pick an operating point like the original paper does.

use crate::baselines::quality_penalty;
use crate::errmodel::{relative_power, SigmaE};
use crate::muldb::MulDb;
use crate::nn::LayerStats;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Chromosome {
    pub tiles: Vec<usize>,      // n_tiles multiplier ids
    pub layer_tile: Vec<usize>, // l entries in [0, n_tiles)
}

impl Chromosome {
    pub fn assignment(&self) -> Vec<usize> {
        self.layer_tile.iter().map(|&t| self.tiles[t]).collect()
    }
}

#[derive(Debug, Clone)]
pub struct GaConfig {
    pub n_tiles: usize,
    pub population: usize,
    pub generations: usize,
    pub mutation_rate: f64,
    pub seed: u64,
}

impl Default for GaConfig {
    fn default() -> Self {
        GaConfig {
            n_tiles: 4,
            population: 64,
            generations: 60,
            mutation_rate: 0.15,
            seed: 0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Evaluated {
    pub chromosome: Chromosome,
    pub power: f64,
    pub penalty: f64,
}

fn evaluate(c: &Chromosome, db: &MulDb, se: &SigmaE, sigma_g: &[f64], stats: &[LayerStats]) -> Evaluated {
    let a = c.assignment();
    Evaluated {
        chromosome: c.clone(),
        power: relative_power(db, stats, &a),
        penalty: quality_penalty(se, sigma_g, &a),
    }
}

fn dominates(a: &Evaluated, b: &Evaluated) -> bool {
    (a.power <= b.power && a.penalty <= b.penalty)
        && (a.power < b.power || a.penalty < b.penalty)
}

/// Nondominated subset (first Pareto front).
pub fn pareto_front(pop: &[Evaluated]) -> Vec<Evaluated> {
    pop.iter()
        .filter(|a| !pop.iter().any(|b| dominates(b, a)))
        .cloned()
        .collect()
}

fn random_chromosome(rng: &mut Rng, m: usize, l: usize, n_tiles: usize) -> Chromosome {
    Chromosome {
        tiles: (0..n_tiles).map(|_| rng.below(m)).collect(),
        layer_tile: (0..l).map(|_| rng.below(n_tiles)).collect(),
    }
}

fn crossover(rng: &mut Rng, a: &Chromosome, b: &Chromosome) -> Chromosome {
    let tiles = a
        .tiles
        .iter()
        .zip(&b.tiles)
        .map(|(&x, &y)| if rng.f64() < 0.5 { x } else { y })
        .collect();
    let cut = rng.below(a.layer_tile.len().max(1));
    let mut layer_tile = a.layer_tile[..cut].to_vec();
    layer_tile.extend_from_slice(&b.layer_tile[cut..]);
    Chromosome { tiles, layer_tile }
}

fn mutate(rng: &mut Rng, c: &mut Chromosome, m: usize, rate: f64) {
    let n_tiles = c.tiles.len();
    for t in c.tiles.iter_mut() {
        if rng.f64() < rate {
            *t = rng.below(m);
        }
    }
    for lt in c.layer_tile.iter_mut() {
        if rng.f64() < rate {
            *lt = rng.below(n_tiles);
        }
    }
}

/// Run the evolution; returns the final population's Pareto front sorted
/// by power (ascending).
pub fn evolve(
    db: &MulDb,
    se: &SigmaE,
    sigma_g: &[f64],
    stats: &[LayerStats],
    cfg: &GaConfig,
) -> Vec<Evaluated> {
    let m = db.len();
    let l = se.l;
    let mut rng = Rng::new(cfg.seed);
    let mut pop: Vec<Evaluated> = (0..cfg.population)
        .map(|_| evaluate(&random_chromosome(&mut rng, m, l, cfg.n_tiles), db, se, sigma_g, stats))
        .collect();

    for _gen in 0..cfg.generations {
        let mut children = Vec::with_capacity(cfg.population);
        while children.len() < cfg.population {
            // binary tournaments on Pareto dominance, tie-break on penalty
            let pick = |rng: &mut Rng, pop: &[Evaluated]| -> usize {
                let i = rng.below(pop.len());
                let j = rng.below(pop.len());
                if dominates(&pop[i], &pop[j]) {
                    i
                } else if dominates(&pop[j], &pop[i]) {
                    j
                } else if pop[i].penalty <= pop[j].penalty {
                    i
                } else {
                    j
                }
            };
            let pa = pick(&mut rng, &pop);
            let pb = pick(&mut rng, &pop);
            let mut child = crossover(&mut rng, &pop[pa].chromosome, &pop[pb].chromosome);
            mutate(&mut rng, &mut child, m, cfg.mutation_rate);
            children.push(evaluate(&child, db, se, sigma_g, stats));
        }
        // elitist merge: parents + children, keep nondominated first, fill
        // by penalty-then-power.
        pop.extend(children);
        let front = pareto_front(&pop);
        let mut next = front;
        if next.len() < cfg.population {
            let mut rest: Vec<Evaluated> = pop
                .iter()
                .filter(|e| !next.iter().any(|f| f.power == e.power && f.penalty == e.penalty))
                .cloned()
                .collect();
            rest.sort_by(|a, b| {
                (a.penalty, a.power)
                    .partial_cmp(&(b.penalty, b.power))
                    .unwrap()
            });
            next.extend(rest.into_iter().take(cfg.population - next.len()));
        } else {
            next.truncate(cfg.population);
        }
        pop = next;
    }

    let mut front = pareto_front(&pop);
    front.sort_by(|a, b| a.power.partial_cmp(&b.power).unwrap());
    front.dedup_by(|a, b| a.power == b.power && a.penalty == b.penalty);
    front
}

/// Convenience: lowest-power front member whose penalty is ~zero.
pub fn pick_feasible(front: &[Evaluated]) -> Option<&Evaluated> {
    front
        .iter()
        .filter(|e| e.penalty <= 1e-9)
        .min_by(|a, b| a.power.partial_cmp(&b.power).unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::errmodel::sigma_e;

    fn setup() -> (MulDb, SigmaE, Vec<f64>, Vec<LayerStats>) {
        let db = MulDb::generate();
        let stats: Vec<LayerStats> = (0..6)
            .map(|i| LayerStats {
                name: format!("l{i}"),
                act_hist: vec![1.0 / 256.0; 256],
                w_hist: vec![1.0 / 256.0; 256],
                k_fanin: 64,
                macs_total: 10_000 * (1 + i),
                s_act: 0.02,
                z_act: 128,
                s_w: 0.01,
                z_w: 128,
                bn_scale: 0.3,
                out_rms: 1.0,
            })
            .collect();
        let se = sigma_e(&db, &stats);
        let sigma_g: Vec<f64> = (0..6).map(|i| 0.1 * (1.0 + i as f64)).collect();
        (db, se, sigma_g, stats)
    }

    #[test]
    fn chromosome_uses_at_most_n_tiles() {
        let (db, se, sigma_g, stats) = setup();
        let cfg = GaConfig {
            n_tiles: 3,
            population: 24,
            generations: 10,
            ..Default::default()
        };
        let front = evolve(&db, &se, &sigma_g, &stats, &cfg);
        assert!(!front.is_empty());
        for e in &front {
            let distinct: std::collections::BTreeSet<usize> =
                e.chromosome.assignment().into_iter().collect();
            assert!(distinct.len() <= 3);
        }
    }

    #[test]
    fn front_is_nondominated_and_finds_feasible() {
        let (db, se, sigma_g, stats) = setup();
        let cfg = GaConfig {
            population: 48,
            generations: 30,
            ..Default::default()
        };
        let front = evolve(&db, &se, &sigma_g, &stats, &cfg);
        for a in &front {
            for b in &front {
                assert!(!dominates(a, b) || a.power == b.power);
            }
        }
        let feasible = pick_feasible(&front);
        assert!(feasible.is_some(), "GA found no zero-penalty solution");
        assert!(feasible.unwrap().power < 1.0, "should beat exact-everywhere");
    }

    #[test]
    fn evolution_improves_over_random_init() {
        let (db, se, sigma_g, stats) = setup();
        let short = evolve(&db, &se, &sigma_g, &stats, &GaConfig { generations: 1, seed: 5, ..Default::default() });
        let long = evolve(&db, &se, &sigma_g, &stats, &GaConfig { generations: 40, seed: 5, ..Default::default() });
        let best = |front: &[Evaluated]| {
            pick_feasible(front).map(|e| e.power).unwrap_or(1.0)
        };
        assert!(best(&long) <= best(&short) + 1e-9);
    }
}
