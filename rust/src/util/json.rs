//! Minimal JSON codec (serde is not available offline — see DESIGN.md).
//!
//! Supports the full JSON grammar we exchange with the Python side:
//! objects, arrays, strings (with escapes), f64 numbers, bools, null.
//! Object key order is preserved (Vec of pairs) so emitted files diff
//! cleanly against Python's output.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_f64()).collect())
    }

    /// Convenience builder: object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Object contents as a map (for lookup-heavy consumers).
    pub fn to_map(&self) -> BTreeMap<String, Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().cloned().collect(),
            _ => BTreeMap::new(),
        }
    }
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {s:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".into()),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or("bad \\u escape")?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or("bad hex in \\u")?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    other => return Err(format!("bad escape {other:?}")),
                },
                Some(c) => {
                    // raw UTF-8 passthrough: collect continuation bytes
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let start = self.pos - 1;
                        for _ in 1..len {
                            self.bump();
                        }
                        out.push_str(
                            std::str::from_utf8(&self.bytes[start..self.pos])
                                .map_err(|e| e.to_string())?,
                        );
                    }
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(pairs)),
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

pub fn to_string(v: &Json) -> String {
    let mut s = String::new();
    write_value(v, &mut s, 0, false);
    s
}

pub fn to_string_pretty(v: &Json) -> String {
    let mut s = String::new();
    write_value(v, &mut s, 0, true);
    s
}

fn write_value(v: &Json, out: &mut String, indent: usize, pretty: bool) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Json::Str(s) => write_string(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                }
                write_value(item, out, indent + 1, pretty);
            }
            if pretty && !items.is_empty() {
                out.push('\n');
                out.push_str(&" ".repeat(indent));
            }
            out.push(']');
        }
        Json::Obj(pairs) => {
            out.push('{');
            for (i, (k, val)) in pairs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                }
                write_string(k, out);
                out.push(':');
                if pretty {
                    out.push(' ');
                }
                write_value(val, out, indent + 1, pretty);
            }
            if pretty && !pairs.is_empty() {
                out.push('\n');
                out.push_str(&" ".repeat(indent));
            }
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_i64(), Some(1));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
        let re = parse(&to_string(&v)).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn parse_unicode_escape() {
        let v = parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("1 2").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
    }

    #[test]
    fn preserves_key_order() {
        let v = parse(r#"{"z": 1, "a": 2}"#).unwrap();
        if let Json::Obj(pairs) = &v {
            assert_eq!(pairs[0].0, "z");
            assert_eq!(pairs[1].0, "a");
        } else {
            panic!()
        }
    }

    #[test]
    fn writes_integers_without_fraction() {
        assert_eq!(to_string(&Json::Num(42.0)), "42");
        assert_eq!(to_string(&Json::Num(0.5)), "0.5");
    }
}
