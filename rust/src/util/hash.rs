//! FNV-1a hashing, shared by every fingerprint in the codebase (plan
//! `config_hash` provenance, the engine's weight-cache staleness tags).
//! One home for the constants so a future widening touches one file.

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0100_0000_01b3;

/// FNV-1a over a byte stream (the canonical formulation).
pub fn fnv1a_bytes(bytes: impl IntoIterator<Item = u8>) -> u64 {
    bytes.into_iter().fold(FNV_OFFSET, |h, b| (h ^ b as u64).wrapping_mul(FNV_PRIME))
}

/// FNV-1a folding whole `u64` words per step — same constants, ~4-8x
/// fewer multiplies than the byte form for wide integer payloads (the
/// engine hashes weight-code vectors on a warm-ish path).  Not
/// byte-compatible with [`fnv1a_bytes`]; pick one per use and stick
/// with it.
pub fn fnv1a_words(words: impl IntoIterator<Item = u64>) -> u64 {
    words.into_iter().fold(FNV_OFFSET, |h, w| (h ^ w).wrapping_mul(FNV_PRIME))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_form_matches_known_vectors() {
        // FNV-1a test vectors: empty input = offset basis, "a" = well-known
        assert_eq!(fnv1a_bytes([]), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a_bytes(*b"a"), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn word_form_is_order_and_content_sensitive() {
        assert_ne!(fnv1a_words([1u64, 2]), fnv1a_words([2u64, 1]));
        assert_ne!(fnv1a_words([1u64, 2]), fnv1a_words([1u64, 3]));
        assert_eq!(fnv1a_words([1u64, 2]), fnv1a_words([1u64, 2]));
    }
}
