//! Deterministic PRNG (SplitMix64) — the offline substitute for `rand`.
//!
//! Used by k-means++ seeding, the genetic baseline, property tests and
//! workload generators.  Deterministic across platforms; never use for
//! anything security-sensitive.

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng {
            state: seed.wrapping_add(0x9E3779B97F4A7C15),
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Derive an independent child stream.  Each distinct `stream` tag
    /// yields a decorrelated generator, and the derivation itself is
    /// deterministic: the same parent state and tag always produce the
    /// same child.  The bench harness uses one stream per concern
    /// (arrivals, batch mix, image picks) so adding draws to one
    /// concern never perturbs the others.
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (inter-arrival sampling).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k.min(n));
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_is_deterministic_and_streams_decorrelate() {
        let seq = |seed: u64, stream: u64| -> Vec<u64> {
            let mut child = Rng::new(seed).fork(stream);
            (0..32).map(|_| child.next_u64()).collect()
        };
        // same parent seed + stream tag -> identical child stream
        assert_eq!(seq(42, 1), seq(42, 1));
        // different tags (and different parents) -> different streams
        assert_ne!(seq(42, 1), seq(42, 2));
        assert_ne!(seq(42, 1), seq(43, 1));
        // forking must not collapse onto the parent's own sequence
        let mut parent = Rng::new(42);
        let mut forked = parent.fork(7);
        let a: Vec<u64> = (0..32).map(|_| parent.next_u64()).collect();
        let b: Vec<u64> = (0..32).map(|_| forked.next_u64()).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
