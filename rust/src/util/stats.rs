//! Small statistics helpers: summaries + latency histograms.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile via linear interpolation on a sorted copy (p in [0, 100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
    }
}

/// Fixed-bucket log-scale latency histogram (microseconds).
/// Lock-free-enough for our use: one per worker, merged at report time.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// bucket i covers [2^i, 2^(i+1)) microseconds; 32 buckets ~ 71 min.
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: vec![0; 32],
            count: 0,
            sum_us: 0,
            max_us: 0,
        }
    }

    pub fn record_us(&mut self, us: u64) {
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(31);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn record(&mut self, d: std::time::Duration) {
        self.record_us(d.as_micros() as u64);
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Approximate percentile from bucket boundaries (upper bound).
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((p / 100.0) * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max_us
    }

    /// The histogram of samples recorded since `earlier` was captured:
    /// per-bucket and counter subtraction.  `earlier` must be a past
    /// snapshot of this same (cumulative, monotone) histogram; buckets
    /// saturate at zero so a mismatched pair degrades to empty rather
    /// than panicking.  `max_us` cannot be un-merged, so the window
    /// inherits the cumulative max — an upper bound, same spirit as the
    /// log2 percentile bounds.
    pub fn since(&self, earlier: &LatencyHistogram) -> LatencyHistogram {
        let mut delta = LatencyHistogram::new();
        for (d, (now, then)) in
            delta.buckets.iter_mut().zip(self.buckets.iter().zip(&earlier.buckets))
        {
            *d = now.saturating_sub(*then);
        }
        delta.count = self.count.saturating_sub(earlier.count);
        delta.sum_us = self.sum_us.saturating_sub(earlier.sum_us);
        delta.max_us = if delta.count == 0 { 0 } else { self.max_us };
        delta
    }

    /// The standard quantile summary (count, mean, p50/p95/p99, max) in
    /// one call — the reusable extraction consumers like the serving
    /// report, `benches/perf_server.rs` and the bench orchestrator
    /// share instead of duplicating percentile math.  Percentiles carry
    /// the same upper-bound semantics as
    /// [`percentile_us`](Self::percentile_us): the true quantile lies in
    /// `(p/2, p]` for the log2 bucketing.
    pub fn summary(&self) -> LatencySummary {
        LatencySummary {
            count: self.count,
            mean_us: self.mean_us(),
            p50_us: self.percentile_us(50.0),
            p95_us: self.percentile_us(95.0),
            p99_us: self.percentile_us(99.0),
            max_us: self.max_us,
        }
    }
}

/// Quantile summary of one [`LatencyHistogram`], extracted by
/// [`LatencyHistogram::summary`].  All times in microseconds; the
/// percentiles are log2-bucket upper bounds (within 2x of exact).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LatencySummary {
    pub count: u64,
    pub mean_us: f64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_percentiles_monotone() {
        let mut h = LatencyHistogram::new();
        for us in [10u64, 20, 40, 80, 160, 320, 5000] {
            h.record_us(us);
        }
        assert!(h.percentile_us(50.0) <= h.percentile_us(99.0));
        assert_eq!(h.count(), 7);
        assert_eq!(h.max_us(), 5000);
    }

    #[test]
    fn summary_quantiles_match_sorted_vector_oracle_within_bucketing() {
        // oracle: ceil-rank selection on the sorted raw samples — the
        // histogram's bucket upper bound must bracket it within 2x
        // (bucket i covers [2^i, 2^(i+1)))
        let mut rng = crate::util::rng::Rng::new(17);
        let samples: Vec<u64> = (0..5000).map(|_| 1 + rng.below(400_000) as u64).collect();
        let mut h = LatencyHistogram::new();
        for &s in &samples {
            h.record_us(s);
        }
        let mut sorted = samples.clone();
        sorted.sort_unstable();
        let oracle = |p: f64| -> u64 {
            let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
            sorted[rank.max(1) - 1]
        };
        let s = h.summary();
        for (p, got) in [(50.0, s.p50_us), (95.0, s.p95_us), (99.0, s.p99_us)] {
            let exact = oracle(p);
            assert!(got > exact, "p{p}: bucket bound {got} must exceed oracle {exact}");
            assert!(got <= 2 * exact, "p{p}: bucket bound {got} vs oracle {exact} (>2x off)");
        }
        assert_eq!(s.count, samples.len() as u64);
        assert_eq!(s.max_us, *sorted.last().unwrap());
        let exact_mean = samples.iter().sum::<u64>() as f64 / samples.len() as f64;
        assert!((s.mean_us - exact_mean).abs() < 1e-9);
    }

    #[test]
    fn empty_summary_is_all_zero() {
        assert_eq!(LatencyHistogram::new().summary(), LatencySummary::default());
    }

    #[test]
    fn since_isolates_the_window() {
        let mut h = LatencyHistogram::new();
        for us in [10u64, 20, 40] {
            h.record_us(us);
        }
        let snap = h.clone();
        for us in [100_000u64, 200_000, 400_000] {
            h.record_us(us);
        }
        let win = h.since(&snap);
        assert_eq!(win.count(), 3);
        // the slow window's p50 reflects only the new samples, not the
        // fast prefix the cumulative histogram would average in
        assert!(win.percentile_us(50.0) > 100_000, "window p50 {}", win.percentile_us(50.0));
        assert!(h.percentile_us(50.0) <= 128, "cumulative p50 {}", h.percentile_us(50.0));
        // empty window degrades to all-zero
        let none = h.since(&h.clone());
        assert_eq!(none.count(), 0);
        assert_eq!(none.summary(), LatencySummary::default());
    }

    #[test]
    fn histogram_merge() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record_us(100);
        b.record_us(1000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
    }
}
