//! Tiny benchmarking harness (criterion is unavailable offline).
//!
//! `cargo bench` runs the `benches/*.rs` binaries with `harness = false`;
//! they use this module for warmup + repeated timing with mean/p50/p95.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean_s
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean,
        p50_s: samples[samples.len() / 2],
        p95_s: samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)],
        min_s: samples[0],
    }
}

/// Pretty-print with an optional throughput annotation.
pub fn report(r: &BenchResult, throughput: Option<(f64, &str)>) {
    let tp = throughput
        .map(|(items, unit)| format!("  {:>10.2} {unit}", items / r.mean_s))
        .unwrap_or_default();
    println!(
        "{:40} mean {:>9.3}ms  p50 {:>9.3}ms  p95 {:>9.3}ms  min {:>9.3}ms{}",
        r.name,
        r.mean_s * 1e3,
        r.p50_s * 1e3,
        r.p95_s * 1e3,
        r.min_s * 1e3,
        tp
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleep() {
        let r = bench("sleep", 1, 5, || std::thread::sleep(std::time::Duration::from_millis(2)));
        assert!(r.mean_s >= 0.002);
        assert!(r.p50_s <= r.p95_s + 1e-9);
    }
}
