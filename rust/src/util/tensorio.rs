//! QTEN named-tensor container reader/writer (Python: compile/tensorio.py).
//!
//! Layout: b"QTEN" | u32 header_len | header JSON | raw little-endian data.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::json::{self, Json};

#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
    U8 { shape: Vec<usize>, data: Vec<u8> },
}

impl Tensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } | Tensor::U8 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    /// f32 view with i32/u8 promotion (labels are stored as i32).
    pub fn to_f32_vec(&self) -> Vec<f32> {
        match self {
            Tensor::F32 { data, .. } => data.clone(),
            Tensor::I32 { data, .. } => data.iter().map(|&v| v as f32).collect(),
            Tensor::U8 { data, .. } => data.iter().map(|&v| v as f32).collect(),
        }
    }

    fn dtype_str(&self) -> &'static str {
        match self {
            Tensor::F32 { .. } => "f32",
            Tensor::I32 { .. } => "i32",
            Tensor::U8 { .. } => "u8",
        }
    }

    fn raw_bytes(&self) -> Vec<u8> {
        match self {
            Tensor::F32 { data, .. } => data.iter().flat_map(|v| v.to_le_bytes()).collect(),
            Tensor::I32 { data, .. } => data.iter().flat_map(|v| v.to_le_bytes()).collect(),
            Tensor::U8 { data, .. } => data.clone(),
        }
    }
}

pub fn load(path: impl AsRef<Path>) -> Result<HashMap<String, Tensor>> {
    let path = path.as_ref();
    let mut f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != b"QTEN" {
        bail!("{}: bad magic {:?}", path.display(), magic);
    }
    let mut lenb = [0u8; 4];
    f.read_exact(&mut lenb)?;
    let hlen = u32::from_le_bytes(lenb) as usize;
    let mut hbuf = vec![0u8; hlen];
    f.read_exact(&mut hbuf)?;
    let header = json::parse(std::str::from_utf8(&hbuf)?).map_err(|e| anyhow::anyhow!(e))?;
    let mut rest = Vec::new();
    f.read_to_end(&mut rest)?;

    let mut out = HashMap::new();
    for e in header.req("tensors").map_err(anyhow::Error::msg)?.as_arr().unwrap_or(&[]) {
        let name = e.get("name").and_then(|v| v.as_str()).context("tensor name")?.to_string();
        let dtype = e.get("dtype").and_then(|v| v.as_str()).context("dtype")?;
        let shape: Vec<usize> = e
            .get("shape")
            .and_then(|v| v.as_arr())
            .context("shape")?
            .iter()
            .map(|v| v.as_usize().unwrap_or(0))
            .collect();
        let offset = e.get("offset").and_then(|v| v.as_usize()).context("offset")?;
        let nbytes = e.get("nbytes").and_then(|v| v.as_usize()).context("nbytes")?;
        let raw = rest
            .get(offset..offset + nbytes)
            .with_context(|| format!("{name}: out-of-bounds tensor data"))?;
        let t = match dtype {
            "f32" => Tensor::F32 {
                shape,
                data: raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
            },
            "i32" => Tensor::I32 {
                shape,
                data: raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect(),
            },
            "u8" => Tensor::U8 {
                shape,
                data: raw.to_vec(),
            },
            other => bail!("{name}: unsupported dtype {other}"),
        };
        out.insert(name, t);
    }
    Ok(out)
}

pub fn save(path: impl AsRef<Path>, tensors: &[(String, Tensor)]) -> Result<()> {
    let mut entries = Vec::new();
    let mut blob = Vec::new();
    for (name, t) in tensors {
        let raw = t.raw_bytes();
        entries.push(Json::obj(vec![
            ("name", Json::str(name.clone())),
            ("dtype", Json::str(t.dtype_str())),
            (
                "shape",
                Json::Arr(t.shape().iter().map(|&d| Json::num(d as f64)).collect()),
            ),
            ("offset", Json::num(blob.len() as f64)),
            ("nbytes", Json::num(raw.len() as f64)),
        ]));
        blob.extend_from_slice(&raw);
    }
    let header = json::to_string(&Json::obj(vec![("tensors", Json::Arr(entries))]));
    let mut f = std::fs::File::create(path)?;
    f.write_all(b"QTEN")?;
    f.write_all(&(header.len() as u32).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    f.write_all(&blob)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("qten_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.qten");
        let tensors = vec![
            (
                "a".to_string(),
                Tensor::F32 {
                    shape: vec![2, 3],
                    data: vec![1.0, -2.5, 3.0, 0.0, 1e-9, 7.25],
                },
            ),
            (
                "b".to_string(),
                Tensor::I32 {
                    shape: vec![4],
                    data: vec![-1, 0, 255, 1 << 20],
                },
            ),
            (
                "c".to_string(),
                Tensor::U8 {
                    shape: vec![3],
                    data: vec![0, 128, 255],
                },
            ),
        ];
        save(&path, &tensors).unwrap();
        let loaded = load(&path).unwrap();
        for (name, t) in &tensors {
            assert_eq!(loaded.get(name).unwrap(), t, "{name}");
        }
    }
}
