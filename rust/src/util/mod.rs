//! Shared substrates: JSON codec, tensor container IO, deterministic PRNG,
//! statistics helpers.  These stand in for `serde`/`rand`/`hdrhistogram`,
//! which are unavailable in the offline build (DESIGN.md substitutions).

pub mod bench;
pub mod hash;
pub mod json;
pub mod rng;
pub mod stats;
pub mod tensorio;
